"""Live Synergy round-based runtime: REAL JAX training jobs (reduced
assigned-arch configs) scheduled with real CPU-worker / MinIO-cache leases.
Reduced-scale analogue of the paper's physical-cluster experiment (Table 5).

    PYTHONPATH=src python examples/synergy_live.py
"""
from repro.core.runtime import LiveJobSpec, LiveRuntime


def main():
    rt = LiveRuntime(n_servers=1, policy="srtf", allocator="tune",
                     round_seconds=1.5, probe_iters=1)
    rt.submit(LiveJobSpec(0, "phi-3-vision-4.2b", total_iters=10, batch_size=4,
                          preprocess_cost_s=0.01, dataset_gb=0.4, seq_len=16))
    rt.submit(LiveJobSpec(1, "qwen2-0.5b", total_iters=10, batch_size=4,
                          preprocess_cost_s=0.0005, dataset_gb=0.1, seq_len=16))
    rt.submit(LiveJobSpec(2, "whisper-large-v3", total_iters=8, batch_size=4,
                          preprocess_cost_s=0.006, dataset_gb=0.4, seq_len=16))
    for jid, lj in rt.jobs.items():
        j = lj.sched_job
        print(f"job{jid} {lj.spec.arch_id}: demand=({j.demand_cpu:.0f} cpu, "
              f"{j.demand_mem:.2f} GB), prop_rate={j.prop_rate:.1f} samp/s, "
              f"max_rate={j.matrix.max_rate():.1f}")
    metrics = rt.run(max_rounds=60)
    print("metrics:", {k: (round(v, 2) if isinstance(v, float) else v)
                       for k, v in metrics.items()})


if __name__ == "__main__":
    main()
