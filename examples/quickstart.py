"""Quickstart: train a reduced llama3.2 on the synthetic pipeline, then
serve a few greedy tokens from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, DataPipeline
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = get_config("llama3.2-1b", smoke=True)
    print(f"arch={cfg.arch_id} (reduced: {cfg.n_layers}L d={cfg.d_model}) "
          f"params={cfg.param_count() / 1e6:.1f}M")

    data = DataPipeline(
        DataConfig(n_samples=512, seq_len=64, vocab_size=cfg.vocab_size),
        batch_size=8, n_workers=2)
    trainer = Trainer(cfg, TrainerConfig(total_steps=30, peak_lr=1e-3))
    hist = trainer.fit(data.batches(30))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({np.mean([h['step_seconds'] for h in hist[5:]]) * 1e3:.0f} ms/step)")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must go down"

    engine = ServeEngine(cfg, params=trainer.state["params"], max_len=48)
    reqs = [Request(np.array([5, 6, 7], np.int32), max_new_tokens=8),
            Request(np.array([9, 10], np.int32), max_new_tokens=8)]
    for r in engine.generate(reqs):
        print("generated:", r.output)


if __name__ == "__main__":
    main()
