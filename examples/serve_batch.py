"""Batched serving demo: two architectures (attention + SSM families)
serving a batch of requests through the same engine API.

    PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np

from repro.configs import get_config
from repro.serve.engine import Request, ServeEngine


def main():
    for arch in ("qwen2-0.5b", "mamba2-780m"):
        cfg = get_config(arch, smoke=True)
        engine = ServeEngine(cfg, max_len=64)
        reqs = [Request(np.arange(3, 9, dtype=np.int32), max_new_tokens=6),
                Request(np.arange(20, 24, dtype=np.int32), max_new_tokens=6),
                Request(np.arange(40, 42, dtype=np.int32), max_new_tokens=6)]
        out = engine.generate(reqs)
        print(f"{arch}:")
        for r in out:
            print(f"  prompt={r.prompt.tolist()} -> {r.output}")


if __name__ == "__main__":
    main()
