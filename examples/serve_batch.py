"""Batched serving demo: two architectures (attention + SSM families)
serving the same request set statically and with continuous batching —
per-request outputs are identical in both modes.

    PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np

from repro.configs import get_config
from repro.serve import ServeEngine, ServeRequest


def requests():
    return [ServeRequest(np.arange(3, 9, dtype=np.int32), max_new_tokens=6),
            ServeRequest(np.arange(20, 24, dtype=np.int32), max_new_tokens=6,
                         arrival_time=2.0),
            ServeRequest(np.arange(40, 42, dtype=np.int32), max_new_tokens=6,
                         arrival_time=4.0)]


def main():
    for arch in ("qwen2-0.5b", "mamba2-780m"):
        cfg = get_config(arch, smoke=True)
        static = ServeEngine(cfg, max_len=64)
        out_s = static.generate([ServeRequest(r.prompt, r.max_new_tokens)
                                 for r in requests()])

        continuous = ServeEngine(cfg, max_len=64, n_slots=2, policy="fcfs")
        out_c, stats = continuous.run(requests())

        print(f"{arch}: (continuous: {stats.steps} steps, "
              f"{stats.slot_utilization:.0%} slot utilization)")
        for rs, rc in zip(out_s, out_c):
            match = "==" if rs.output == rc.output else "!="
            print(f"  prompt={rs.prompt.tolist()} -> {rc.output} "
                  f"(static {match} continuous)")


if __name__ == "__main__":
    main()
