"""End-to-end Synergy demo: profile a job mix, schedule one round with every
mechanism, then run the full event simulation comparing GPU-proportional
against Synergy-TUNE (and the Synergy-OPT bound).

    PYTHONPATH=src python examples/cluster_scheduling.py
"""
import copy

from repro.core import opt
from repro.core.allocators import get_allocator
from repro.core.cluster import Cluster
from repro.core.policies import get_policy
from repro.core.profiler import OptimisticProfiler
from repro.core.simulator import simulate
from repro.core.trace import TraceConfig, generate


def main():
    jobs = generate(TraceConfig(n_jobs=48, split=(40, 40, 20),
                                arrival="static", seed=3))
    cluster = Cluster(4)                        # 32 GPUs, paper's testbed size
    prof = OptimisticProfiler(cluster.spec)
    for j in jobs:
        prof.profile_job(j)

    print("== optimistic profiles (first 6 jobs) ==")
    for j in jobs[:6]:
        print(f"  job{j.job_id:<3} {j.model_name:<14} g={j.gpu_demand} "
              f"demand=({j.demand_cpu:.0f} cpu, {j.demand_mem:.0f} GB) "
              f"probes={j.matrix.profile_probes}")

    print("\n== one round, all mechanisms (32 GPUs) ==")
    order = get_policy("fifo").order(jobs, 0)
    for name in ("proportional", "greedy", "tune"):
        cl = Cluster(4)
        js = copy.deepcopy(order)
        plan = get_allocator(name).schedule(cl, js)
        tput = sum(j.current_rate / j.prop_rate for j in js if j.current_rate > 0)
        print(f"  {name:<13} scheduled={len(plan.scheduled):<3} "
              f"gpu_util={cl.utilization()['gpu'] * 100:3.0f}% "
              f"sum_speedup={tput:.1f}")
    res = opt.solve_ideal([j for j in order if j.matrix], cluster, integer=True)
    print(f"  OPT bound: throughput gain {res.throughput / res.fair_throughput:.2f}x"
          f" (solve {res.solve_seconds * 1e3:.0f} ms)")

    print("\n== full simulation (makespan, static FIFO trace) ==")
    for name in ("proportional", "tune"):
        r = simulate(4, copy.deepcopy(jobs), policy="fifo", allocator=name)
        print(f"  {name:<13} makespan={r.makespan / 3600:6.1f}h "
              f"avg_jct={r.avg_jct / 3600:6.1f}h")


if __name__ == "__main__":
    main()
