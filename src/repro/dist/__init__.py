"""repro.dist — device-mesh distribution subsystem.

``repro.dist.sharding`` is the logical-axis sharding layer used by every
model family, the serve engine, and the multi-pod dry-run. See
src/repro/dist/README.md for the design.
"""
from repro.dist import sharding
from repro.dist.sharding import (Rules, attention_scheme, axis_rules,
                                 current_rules, named, param_pspecs,
                                 production_rules_table, shard, shard_spec)

__all__ = [
    "sharding", "Rules", "attention_scheme", "axis_rules", "current_rules",
    "named", "param_pspecs", "production_rules_table", "shard", "shard_spec",
]
