"""Logical-axis sharding rules over a device mesh.

Model code never names mesh axes directly. It annotates activations with
*logical* axis names (``batch``, ``kv_seq``, ``ffn``, ``vocab``, ``experts``,
``inner_flat``, ``heads``/``heads_flat``, ``embed``) and a *rules table* maps
each logical name to zero or more mesh axes. The table is installed with the
``axis_rules(mesh, table)`` context manager; all helpers read the innermost
active rules via ``current_rules()``.

The off-mesh contract: when no rules are active (single-host CPU tests, the
live runtime's per-job processes) every helper is an exact no-op —
``shard``/``shard_spec`` return their input unchanged and
``attention_scheme`` returns ``None`` — so the same model code runs anywhere.

On-mesh, every constraint is *sanitized* before it is applied: a mesh axis is
dropped from a PartitionSpec entry when (a) it does not exist on the active
mesh, (b) it was already consumed by an earlier dimension of the same spec,
or (c) the dimension size is not divisible by the axis size. This keeps
annotations best-effort: a table tuned for the 256-chip production mesh
degrades gracefully on an 8-device host mesh or on awkward shapes (GQA head
counts, batch 1) instead of erroring.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, List, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

#: one mesh-axis assignment: nothing, a single axis, or several fused axes
MeshAxes = Union[None, str, Tuple[str, ...]]

__all__ = [
    "Rules", "axis_rules", "current_rules", "shard", "shard_spec",
    "attention_scheme", "production_rules_table", "param_pspecs", "named",
    "PARAM_LOGICAL_AXES",
]


# ---------------------------------------------------------------------------
# rules registry
# ---------------------------------------------------------------------------
class Rules:
    """An installed (mesh, logical-axis table) pair."""

    def __init__(self, mesh, table: Dict[str, MeshAxes]):
        self.mesh = mesh
        self.table: Dict[str, MeshAxes] = {
            k: tuple(v) if isinstance(v, list) else v
            for k, v in dict(table).items()
        }
        self.sizes: Dict[str, int] = dict(
            zip(mesh.axis_names, mesh.devices.shape))

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        """Mesh axes assigned to a logical axis name (None if unmapped)."""
        if logical is None:
            return None
        return self.table.get(logical)

    def axis_size(self, axes: MeshAxes) -> int:
        """Total number of shards over ``axes`` (1 for None)."""
        n = 1
        for a in _flat(axes):
            n *= self.sizes.get(a, 1)
        return n

    def __repr__(self) -> str:
        return f"Rules(mesh={tuple(self.sizes.items())}, table={self.table})"


_STATE = threading.local()


def _stack() -> List[Rules]:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


def current_rules() -> Optional[Rules]:
    """The innermost active Rules, or None when off-mesh."""
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def axis_rules(mesh, table: Dict[str, MeshAxes]):
    """Install ``table`` over ``mesh`` for the dynamic extent of the block."""
    rules = Rules(mesh, table)
    _stack().append(rules)
    try:
        yield rules
    finally:
        _stack().pop()


# ---------------------------------------------------------------------------
# spec construction / sanitization
# ---------------------------------------------------------------------------
def _flat(axes: MeshAxes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, (tuple, list)):
        return tuple(axes)
    return (axes,)


def _sanitize(parts, shape, rules: Rules) -> P:
    """Right-pad ``parts`` to ``shape``'s rank and drop invalid entries
    (unknown mesh axis, duplicate use, non-divisible dimension)."""
    parts = list(parts)[:len(shape)]
    parts += [None] * (len(shape) - len(parts))
    used: set = set()
    out = []
    for dim, ax in zip(shape, parts):
        axes = _flat(ax)
        if (not axes
                or any(a not in rules.sizes for a in axes)
                or any(a in used for a in axes)
                or dim % rules.axis_size(ax) != 0):
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    return P(*out)


def _overlaps(a: MeshAxes, b: MeshAxes) -> bool:
    return bool(set(_flat(a)) & set(_flat(b)))


# ---------------------------------------------------------------------------
# constraint helpers (no-ops off-mesh)
# ---------------------------------------------------------------------------
def shard(x, *logical_axes):
    """Constrain ``x`` by logical axis names, one per dimension.

    ``shard(h, "batch", None, "ffn")`` constrains a [B, S, F] activation to
    (batch-axes, replicated, ffn-axes). Unmapped names, missing trailing
    names, and non-divisible dimensions all degrade to replication.
    """
    rules = current_rules()
    if rules is None:
        return x
    parts = [rules.mesh_axes(name) for name in logical_axes]
    spec = _sanitize(parts, x.shape, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def shard_spec(x, pspec):
    """Constrain ``x`` with an explicit PartitionSpec (mesh-axis names).

    The spec is sanitized against the active mesh and ``x.shape`` first, so
    callers may pass production specs unconditionally.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = _sanitize(tuple(pspec), x.shape, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# attention scheme selection
# ---------------------------------------------------------------------------
def attention_scheme(b: int, s: int, nh: int, kv_s: int):
    """Pick the attention sharding layout for shapes (B, Sq, H, Skv).

    Returns None off-mesh, else {"q", "kv", "logits"} PartitionSpecs laid out
    for q/kv of shape [B, S, H, D] and logits of [B, H, Sq, Sk]:

      * head-sharded   — H divides the 'heads' axes: the classic TP layout.
      * q-seq-sharded  — awkward head count but a long query: shard Sq.
      * kv-seq-sharded — decode (Sq == 1) with awkward heads: shard the
        cache sequence; XLA resolves the sharded softmax reduction with a
        partial-softmax all-reduce.
      * batch-only     — nothing else fits.
    """
    rules = current_rules()
    if rules is None:
        return None

    def fits(n: int, ax: MeshAxes) -> bool:
        size = rules.axis_size(ax)
        return ax is not None and size > 1 and n % size == 0

    b_ax = rules.mesh_axes("batch")
    if not fits(b, b_ax):
        b_ax = None
    m_ax = rules.mesh_axes("heads")
    if m_ax is not None and rules.axis_size(m_ax) <= 1:
        m_ax = None
    kv_ax = rules.mesh_axes("kv_seq")
    if not fits(kv_s, kv_ax) or _overlaps(kv_ax, b_ax):
        kv_ax = None
    if b_ax is None and m_ax is None and kv_ax is None:
        return None

    msize = rules.axis_size(m_ax) if m_ax is not None else 0
    if m_ax is not None and nh % msize == 0:
        kv_seq = kv_ax if not _overlaps(kv_ax, m_ax) else None
        return {"q": P(b_ax, None, m_ax, None),
                "kv": P(b_ax, kv_seq, m_ax, None),
                "logits": P(b_ax, m_ax, None, None)}
    if m_ax is not None and s > 1 and s % msize == 0:
        # long query, non-dividing heads: shard the query sequence; KV is
        # replicated over the head axes so each shard sees every key.
        return {"q": P(b_ax, m_ax, None, None),
                "kv": P(b_ax, kv_ax, None, None),
                "logits": P(b_ax, None, m_ax, None)}
    if m_ax is not None and s == 1 and kv_s % msize == 0:
        return {"q": P(b_ax, None, None, None),
                "kv": P(b_ax, m_ax, None, None),
                "logits": P(b_ax, None, None, m_ax)}
    return {"q": P(b_ax, None, None, None),
            "kv": P(b_ax, kv_ax, None, None),
            "logits": P(b_ax, None, None, None)}


# ---------------------------------------------------------------------------
# production tables / parameter specs (consumed by launch/dryrun.py)
# ---------------------------------------------------------------------------
def production_rules_table(multi_pod: bool = False, *,
                          seq_shard: bool = False) -> Dict[str, MeshAxes]:
    """Logical-axis table for the production meshes in launch/mesh.py.

    Single pod: ("data", "model") = (16, 16); multi-pod adds a leading "pod"
    axis fused into the batch axes. ``seq_shard`` routes kv_seq to "data"
    for the long-context decode shape (batch 1 — the batch axes are idle and
    the sanitizer resolves the data-axis collision in batch's favor
    otherwise). Callers may retarget entries before installing the table,
    e.g. ``table["kv_seq"] = "model"`` for small-KV-head decode.
    """
    batch: MeshAxes = ("pod", "data") if multi_pod else "data"
    return {
        "batch": batch,
        "heads": "model",
        "heads_flat": "model",
        "kv_seq": "data" if seq_shard else None,
        "ffn": "model",
        "vocab": "model",
        "experts": "model",
        "inner_flat": "model",
        "embed": None,
        "model": None,
    }


#: logical axes of each parameter's *trailing* dimensions, keyed by leaf name.
#: Leading stacked-layer / group dimensions are always replicated. Where two
#: entries map to the same mesh axes (e.g. experts and ffn -> "model") the
#: sanitizer keeps the leftmost — expert parallelism wins over TP within an
#: expert, matching the [E, C, D] x [E, D, F] batched-GEMM layout in moe.py.
PARAM_LOGICAL_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings
    "tok_emb": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    # attention
    "wq": ("embed", "heads_flat"),
    "wk": ("embed", "heads_flat"),
    "wv": ("embed", "heads_flat"),
    "bq": ("heads_flat",),
    "bk": ("heads_flat",),
    "bv": ("heads_flat",),
    "wo": ("heads_flat", "embed"),
    # dense MLP
    "w_gate": ("embed", "ffn"),
    "w_up": ("embed", "ffn"),
    "w_down": ("ffn", "embed"),
    # MoE
    "router": ("embed", "experts"),
    "we_gate_up": ("experts", "embed", "ffn"),
    "we_down": ("experts", "ffn", "embed"),
    # Mamba2 / SSD
    "in_proj": ("embed", "inner_flat"),
    "out_proj": ("inner_flat", "embed"),
    "conv_w": (None, "inner_flat"),
    "conv_b": ("inner_flat",),
    "A_log": ("heads",),
    "dt_bias": ("heads",),
    "D": ("heads",),
}


def param_pspecs(pshape, rules: Rules):
    """PartitionSpec pytree for a params shape-tree under ``rules``.

    Leaves are matched by their final path component against
    ``PARAM_LOGICAL_AXES`` (right-aligned over trailing dims); unknown leaves
    (norm scales, anything new) are replicated. Every spec is full-rank and
    sanitized, so the result can go straight into ``named``/``jax.jit``.
    """
    def spec_for(path, leaf):
        name = str(getattr(path[-1], "key", path[-1])) if path else ""
        logical = PARAM_LOGICAL_AXES.get(name, ())
        ndim = len(leaf.shape)
        trailing = [rules.mesh_axes(a) for a in logical[-ndim:]]
        parts = [None] * (ndim - len(trailing)) + trailing
        return _sanitize(parts, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(spec_for, pshape)


def named(spec, mesh):
    """Map a PartitionSpec pytree to NamedShardings on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))
