"""whisper-large-v3 [arXiv:2212.04356] — enc-dec audio; conv frontend stubbed.

32 encoder + 32 decoder layers, d_model=1280, 20 heads (GQA kv=20, i.e. MHA),
d_ff=5120, vocab=51866. The mel+conv frontend is a stub: input_specs supplies
1500 precomputed frame embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-large-v3",
    family="encdec",
    citation="arXiv:2212.04356",
    n_layers=32,            # decoder layers
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    qkv_bias=True,
    pos_emb="sinusoidal",
    enc_seq=1500,
    sens_class="speech",
)
