from repro.configs.base import ArchConfig, smoke_variant
from repro.configs.registry import ARCH_IDS, get_config, list_archs
from repro.configs.shapes import INPUT_SHAPES, InputShape

__all__ = ["ArchConfig", "smoke_variant", "ARCH_IDS", "get_config",
           "list_archs", "INPUT_SHAPES", "InputShape"]
