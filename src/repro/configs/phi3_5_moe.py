"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct] — 16 experts top-2."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    family="moe",
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,              # per-expert FFN width
    vocab_size=32064,
    n_experts=16,
    top_k=2,
    rope_theta=10000.0,
    sens_class="language",
)
