"""qwen2-0.5b [arXiv:2407.10671] — dense, GQA kv=2, QKV bias."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-0.5b",
    family="dense",
    citation="arXiv:2407.10671",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    sens_class="language",
)
