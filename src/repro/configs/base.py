"""Architecture configuration schema.

Every assigned architecture is expressed as an ``ArchConfig``. The same schema
drives model construction (``repro.models.api.build_model``), the dry-run
lowering (``repro.launch.dryrun``), and the Synergy scheduler's workload
classes (``sens_class`` maps an architecture onto the paper's image / language
/ speech sensitivity families).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    # -- identity -----------------------------------------------------------
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    citation: str = ""

    # -- transformer geometry ------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    pos_emb: str = "rope"            # rope | sinusoidal
    rope_theta: float = 10000.0

    # -- attention pattern ---------------------------------------------------
    sliding_window: int = 0          # 0 = full attention
    global_every: int = 0            # gemma3: every Nth layer is global (rest local)

    # -- mixture of experts --------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # -- state-space (mamba2 / SSD) ------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # -- hybrid (zamba2) ------------------------------------------------------
    shared_attn_every: int = 0       # shared attn block before every N ssm blocks

    # -- encoder-decoder (whisper) --------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 0                 # stub-frontend frame count

    # -- vlm ------------------------------------------------------------------
    n_patches: int = 0               # stub-frontend patch count (prefix of sequence)

    # -- numerics -------------------------------------------------------------
    dtype: str = "float32"           # activation dtype
    param_dtype: str = "float32"
    decode_attention: str = "contiguous"  # decode-attention backend per layer:
                                     # contiguous (one [B, max_len] cache row
                                     # per slot) | paged (block-pool KV behind
                                     # a per-request block table — serving)
    remat: str = "none"              # none | dots | full
    use_pallas: bool = False         # route hot-spots through Pallas kernels
    unroll: bool = False             # unroll layer loops (dry-run flop probes:
                                     # XLA cost_analysis counts while bodies
                                     # once, so probes compile unrolled)

    # -- beyond-paper perf knobs (EXPERIMENTS.md §Perf) -----------------------
    local_banded: bool = False       # banded (block-local) attention for
                                     # sliding-window layers: O(S*2W) scores
                                     # instead of O(S^2)
    gqa_no_repeat: bool = False      # grouped GQA einsum without KV repeat
                                     # (when kv heads divide the model axis)
    pad_q_heads: int = 0             # pad Q heads to this count (zero-init
                                     # extra wo rows) so heads shard cleanly
    moe_gather_dispatch: bool = False  # MoE dispatch via int32 slot->token
                                     # indices + local gather, instead of
                                     # scatter-add of feature buffers (which
                                     # XLA lowers to f32 partial-sum
                                     # all-reduces over the expert axis)

    @property
    def n_heads_eff(self) -> int:
        return self.pad_q_heads if self.pad_q_heads > self.n_heads else self.n_heads

    # -- Synergy workload class (paper Fig. 2 families) -------------------------
    sens_class: str = "language"     # image | language | speech

    # ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic archs that run the long_500k shape (see DESIGN.md)."""
        return self.family in ("ssm", "hybrid") or (
            self.family == "dense" and self.sliding_window > 0
        )

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (for 6·N·D model flops and the throughput model). ------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        v = self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            q = d * self.n_heads * hd + (self.n_heads * hd if self.qkv_bias else 0)
            kv = 2 * (d * self.n_kv_heads * hd + (self.n_kv_heads * hd if self.qkv_bias else 0))
            o = self.n_heads * hd * d
            return q + kv + o

        def mlp_params(ff: int) -> int:
            return 3 * d * ff          # swiglu: gate + up + down

        def moe_params() -> int:
            router = d * self.n_experts
            experts = self.n_experts if not active_only else self.top_k
            return router + experts * mlp_params(self.d_ff)

        def ssm_params() -> int:
            di, st, g, h = self.d_inner, self.ssm_state, self.ssm_groups, self.n_ssm_heads
            in_p = d * (2 * di + 2 * g * st + h)
            conv = (di + 2 * g * st) * self.ssm_conv
            return in_p + conv + h * 2 + di + di * d   # A,dt_bias,D,norm + out_proj

        per_layer = 2 * d              # two norms
        if self.family in ("dense", "vlm"):
            per_layer += attn_params() + mlp_params(self.d_ff)
            total = emb + self.n_layers * per_layer
        elif self.family == "moe":
            per_layer += attn_params() + moe_params()
            total = emb + self.n_layers * per_layer
        elif self.family == "ssm":
            total = emb + self.n_layers * (d + ssm_params())
        elif self.family == "hybrid":
            shared = attn_params() + mlp_params(4 * d) + 2 * d
            total = emb + self.n_layers * (d + ssm_params()) + shared
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            dec = self.n_layers * (2 * attn_params() + mlp_params(self.d_ff) + 3 * d)
            total = emb + enc + dec
        else:
            raise ValueError(self.family)
        return int(total)


# Reduced variant used by per-arch smoke tests: same family / same code paths,
# laptop-scale dimensions (<=2 layers, d_model <= 512, <= 4 experts).
def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    kw = dict(
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        dtype="float32",
        param_dtype="float32",
        remat="none",
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, d_ff=128)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_headdim=32, ssm_chunk=32)
    if cfg.family == "hybrid":
        kw.update(shared_attn_every=2)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, enc_seq=64)
    if cfg.family == "vlm":
        kw.update(n_patches=16)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    return cfg.replace(**kw)
