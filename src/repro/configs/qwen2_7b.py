"""qwen2-7b [arXiv:2407.10671] — dense, GQA kv=4, QKV bias."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-7b",
    family="dense",
    citation="arXiv:2407.10671",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    sens_class="language",
)
