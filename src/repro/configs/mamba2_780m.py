"""mamba2-780m [arXiv:2405.21060] — pure SSM (SSD), attention-free."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="mamba2-780m",
    family="ssm",
    citation="arXiv:2405.21060",
    n_layers=48,
    d_model=1536,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    sens_class="language",
)
