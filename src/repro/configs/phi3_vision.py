"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct].

phi3-mini backbone + CLIP vision encoder; the ViT+projector is a STUB —
input_specs supplies precomputed patch embeddings occupying the sequence
prefix (n_patches positions).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="phi-3-vision-4.2b",
    family="vlm",
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    n_patches=576,
    sens_class="image",
)
