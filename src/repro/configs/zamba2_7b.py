"""zamba2-7b [arXiv:2411.15242] — hybrid: Mamba2 backbone + shared attn block.

81 Mamba2 blocks; a single shared attention(+MLP) block invoked before every
6 blocks (13 invocations + 3 trailing mamba blocks). ssm_state=64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    citation="arXiv:2411.15242",
    n_layers=81,            # mamba2 blocks
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,             # shared-block MLP width
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    shared_attn_every=6,
    sens_class="language",
)
