"""gemma3-27b [hf:google/gemma-3-1b-pt family] — dense, 5:1 local:global.

62 layers; every 6th layer is global attention, the rest use a 1024-token
sliding window — which is what makes long_500k decode tractable (local KV is
window-bounded; global layers are O(L) per decoded token).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma3-27b",
    family="dense",
    citation="hf:google/gemma-3-1b-pt",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    rope_theta=1000000.0,
    sliding_window=1024,
    global_every=6,
    tie_embeddings=True,
    sens_class="language",
)
