"""olmoe-1b-7b [arXiv:2409.02060] — MoE, 64 experts top-8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    citation="arXiv:2409.02060",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,              # per-expert FFN width
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    rope_theta=10000.0,
    sens_class="language",
)
