"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import ArchConfig, smoke_variant

_MODULES = {
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "gemma3-27b": "repro.configs.gemma3_27b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str, smoke: bool = False, **overrides) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    cfg = importlib.import_module(_MODULES[arch_id]).CONFIG
    if smoke:
        cfg = smoke_variant(cfg)
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def list_archs() -> List[str]:
    return list(ARCH_IDS)
