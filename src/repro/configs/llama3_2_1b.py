"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B] — dense, GQA kv=8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama3.2-1b",
    family="dense",
    citation="hf:meta-llama/Llama-3.2-1B",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    tie_embeddings=True,
    sens_class="language",
)
