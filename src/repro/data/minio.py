"""MinIO-style DNN-aware cache model ([41], §3.1, §6).

Properties the paper relies on (and we implement):
  * a FIXED subset of the dataset is cached for an entire epoch — no
    thrashing, so the per-epoch hit rate is exactly capacity/dataset and
    therefore *predictable* (this is what licenses optimistic profiling);
  * per-job isolation: each job owns its cache instance sized by the
    scheduler's memory allocation (unlike the shared OS page cache);
  * capacity is adjustable between rounds when the allocation changes.

The cached subset is chosen deterministically by a multiplicative hash of the
sample index so that resizing keeps a nested subset (a bigger cache strictly
contains a smaller one — no re-warm penalty on grow).
"""
from __future__ import annotations

from dataclasses import dataclass, field


_PHI = 0x9E3779B97F4A7C15
_MASK = (1 << 64) - 1


def _hash01(idx: int) -> float:
    return (((int(idx) + 1) * _PHI) & _MASK) / float(1 << 64)


@dataclass
class MinIOCache:
    n_samples: int
    sample_bytes: int
    capacity_bytes: int = 0
    hits: int = 0
    misses: int = 0

    @property
    def n_cached(self) -> int:
        if self.sample_bytes <= 0:
            return self.n_samples
        return min(self.n_samples, self.capacity_bytes // self.sample_bytes)

    @property
    def hit_rate(self) -> float:
        return self.n_cached / max(self.n_samples, 1)

    def set_capacity(self, capacity_bytes: int) -> None:
        self.capacity_bytes = max(0, int(capacity_bytes))

    def set_capacity_gb(self, gb: float) -> None:
        self.set_capacity(int(gb * (1 << 30)))

    def lookup(self, idx: int) -> bool:
        """True = cache hit. Deterministic nested-subset membership."""
        hit = _hash01(idx) < self.hit_rate
        if hit:
            self.hits += 1
        else:
            self.misses += 1
        return hit

    def observed_hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = 0
