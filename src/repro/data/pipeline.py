"""Input pipeline with REAL, tunable CPU preprocessing cost.

This is the resource Synergy arbitrates, so it is not a stub: every sample is
(1) fetched — cache hit via MinIO or a (simulated or slept) storage read, and
(2) preprocessed — a calibrated numpy compute kernel that releases the GIL,
so the worker-pool size (== the job's CPU allocation) genuinely changes
throughput on a real machine. ``set_workers`` / ``set_cache_gb`` are the two
knobs the Synergy scheduler turns at every round via the iterator lease.

Samples are deterministic functions of (seed, index): the same corpus
regardless of CPU/cache allocation, so training curves are reproducible.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    n_samples: int = 4096
    seq_len: int = 64
    vocab_size: int = 512
    preprocess_cost_s: float = 0.0      # CPU-seconds of work per sample
    sample_bytes: int = 1 << 20          # 1 MB/sample on "storage"
    disk_bw_bytes: float = 500e6         # 500 MB/s
    simulate_io: bool = True             # virtual fetch clock (no sleeping)
    # 'pool': real ThreadPool parallelism (needs >1 physical cores);
    # 'scaled': burn cost/n_workers serially — models ideal CPU scaling, the
    # honest choice on the single-core CI container (see DESIGN.md §9).
    parallel_mode: str = "scaled"
    seed: int = 0


_CAL_LOCK = threading.Lock()
_CAL_OPS_PER_SEC: Optional[float] = None
_CAL_K = 96


def _burn_unit() -> None:
    """One calibration unit of GIL-releasing numpy work."""
    a = np.full((_CAL_K, _CAL_K), 1.0003)
    np.dot(a, a)


def _ops_per_second() -> float:
    global _CAL_OPS_PER_SEC
    with _CAL_LOCK:
        if _CAL_OPS_PER_SEC is None:
            t0 = time.perf_counter()
            n = 0
            while time.perf_counter() - t0 < 0.1:
                _burn_unit()
                n += 1
            _CAL_OPS_PER_SEC = n / (time.perf_counter() - t0)
        return _CAL_OPS_PER_SEC


def _preprocess_burn(cost_s: float) -> None:
    if cost_s <= 0:
        return
    units = max(1, int(cost_s * _ops_per_second()))
    for _ in range(units):
        _burn_unit()


class SyntheticDataset:
    """Deterministic token corpus: sample i is PRNG(seed, i)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def __len__(self) -> int:
        return self.cfg.n_samples

    def raw(self, idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed << 32) ^ idx)
        return rng.integers(0, self.cfg.vocab_size,
                            size=self.cfg.seq_len + 1).astype(np.int32)


class DataPipeline:
    """Fetch -> MinIO cache -> preprocess(worker pool) -> batch."""

    def __init__(self, cfg: DataConfig, batch_size: int,
                 n_workers: int = 1, cache=None):
        from repro.data.minio import MinIOCache
        self.cfg = cfg
        self.dataset = SyntheticDataset(cfg)
        self.batch_size = batch_size
        self.cache = cache or MinIOCache(cfg.n_samples, cfg.sample_bytes)
        self._n_workers = max(1, int(n_workers))
        self._pool = ThreadPoolExecutor(max_workers=self._n_workers)
        self._epoch = 0
        self.virtual_fetch_seconds = 0.0     # simulated storage time
        self.samples_out = 0

    # -- the Synergy knobs -----------------------------------------------------
    def set_workers(self, n: int) -> None:
        n = max(1, int(n))
        if n != self._n_workers:
            old = self._pool
            self._n_workers = n
            self._pool = ThreadPoolExecutor(max_workers=n)
            old.shutdown(wait=False)

    def set_cache_gb(self, gb: float) -> None:
        self.cache.set_capacity_gb(gb)

    @property
    def n_workers(self) -> int:
        return self._n_workers

    # -- sample path -------------------------------------------------------------
    def _fetch(self, idx: int) -> np.ndarray:
        if not self.cache.lookup(idx):
            dt = self.cfg.sample_bytes / self.cfg.disk_bw_bytes
            if self.cfg.simulate_io:
                self.virtual_fetch_seconds += dt
            else:
                time.sleep(dt)
        return self.dataset.raw(idx)

    def _sample(self, idx: int) -> Tuple[np.ndarray, np.ndarray]:
        raw = self._fetch(idx)
        cost = self.cfg.preprocess_cost_s
        if self.cfg.parallel_mode == "scaled":
            cost = cost / self._n_workers
        _preprocess_burn(cost)
        # the actual transform: deterministic augmentation (roll by epoch)
        toks = np.roll(raw, self._epoch)
        return toks[:-1], toks[1:]

    # -- batching ------------------------------------------------------------------
    def epoch_indices(self) -> np.ndarray:
        rng = np.random.default_rng(self.cfg.seed + 7919 * self._epoch)
        return rng.permutation(len(self.dataset))

    def __iter__(self) -> Iterator[dict]:
        idxs = self.epoch_indices()
        n_full = len(idxs) // self.batch_size
        for b in range(n_full):
            batch_idx = idxs[b * self.batch_size:(b + 1) * self.batch_size]
            if self.cfg.parallel_mode == "scaled":
                results = [self._sample(i) for i in batch_idx]
            else:
                results = list(self._pool.map(self._sample, batch_idx))
            tokens = np.stack([r[0] for r in results])
            labels = np.stack([r[1] for r in results])
            self.samples_out += len(batch_idx)
            yield {"tokens": tokens, "labels": labels}
        self._epoch += 1

    def batches(self, n: int) -> Iterator[dict]:
        """Yield exactly n batches, crossing epochs as needed."""
        got = 0
        while got < n:
            for batch in self:
                yield batch
                got += 1
                if got >= n:
                    return

    def close(self) -> None:
        self._pool.shutdown(wait=False)
