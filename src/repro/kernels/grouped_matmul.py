"""Grouped (per-expert) matmul kernel for MoE FFNs on TPU.

Computes out[g] = x[g] @ w[g] for G expert groups with capacity-layout
activations x: [G, C, K] and per-expert weights w: [G, K, N]. Blocked over
(C, N, K) with an f32 VMEM accumulator; K is the innermost grid dimension so
the accumulator persists across K-blocks (sequential TPU grid), exactly like
the flash-attention state carry.

``valid_rows`` (tokens actually routed to each expert, <= capacity) lets the
kernel skip fully-empty row blocks — the TPU analogue of megablocks' ragged
GEMM: instead of CUDA block-sparse tiles we prune whole grid steps with
pl.when, which the sequential grid makes free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(valid_ref, x_ref, w_ref, o_ref, acc_ref, *, bm: int, nk: int):
    mi = pl.program_id(1)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[0]
    run = mi * bm < valid               # any valid row in this block?

    @pl.when(run)
    def _body():
        x = x_ref[0]
        w = w_ref[0]
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(x, w, valid_rows=None, *, bm: int = 128, bn: int = 128,
                   bk: int = 128, interpret: bool = True):
    """x: [G, C, K]; w: [G, K, N]; valid_rows: [G] int32 (None = all valid)."""
    g, c, k = x.shape
    n = w.shape[-1]
    bm, bn, bk = min(bm, c), min(bn, n), min(bk, k)
    assert c % bm == 0 and n % bn == 0 and k % bk == 0, (c, n, k, bm, bn, bk)
    if valid_rows is None:
        valid_rows = jnp.full((g,), c, jnp.int32)
    nk = k // bk

    kernel = functools.partial(_kernel, bm=bm, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(g, c // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda gi, mi, ni, ki: (gi,)),
            pl.BlockSpec((1, bm, bk), lambda gi, mi, ni, ki: (gi, mi, ki)),
            pl.BlockSpec((1, bk, bn), lambda gi, mi, ni, ki: (gi, ki, ni)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, mi, ni, ki: (gi, mi, ni)),
        out_shape=jax.ShapeDtypeStruct((g, c, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(valid_rows, x, w)
