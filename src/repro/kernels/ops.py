"""Jitted public wrappers for the Pallas kernels.

Each wrapper handles layout (head flattening, padding to block multiples),
dtype promotion, and backend selection: on CPU the kernels execute in
``interpret=True`` mode (Python emulation of the kernel body — the
correctness path used by CI); on TPU they compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import grouped_matmul as _gmm
from repro.kernels import paged_attention as _pa
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis: int, mult: int):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    """q: [B, S, Hq, D]; k, v: [B, S, Hkv, D] -> [B, S, Hq, D]."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    blk = min(bq, bk, max(8, s))
    qf, pad = _pad_to(qf, 1, blk)
    kf, _ = _pad_to(kf, 1, blk)
    vf, _ = _pad_to(vf, 1, blk)
    # padded key rows must never be attended: causal masking covers q<=s rows
    # only when causal; otherwise mask via window? -> mask by slicing output
    # and padding k with -inf-free zeros is safe because padded q rows are
    # discarded and padded k rows get zero weight only under causal; for
    # non-causal inputs we require s % blk == 0 (wrapper asserts).
    if not causal and pad:
        raise ValueError("non-causal flash attention requires S % block == 0")
    out = _fa.flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                                   bq=min(bq, qf.shape[1]),
                                   bk=min(bk, kf.shape[1]),
                                   interpret=_interpret())
    out = out[:, :s].reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    return out


@jax.jit
def paged_attention(q, k_pages, v_pages, tables, pos, window=0):
    """q: [B, Hq, D]; k_pages, v_pages: [NB, BS, Hkv, D]; tables: [B, MB]
    int32 block ids (-1 = unassigned); pos: [B] int32; window: int32 scalar
    (0 = full attention; dynamic — gemma3's per-layer windows are traced).
    Returns [B, Hq, D]. Q heads are grouped per kv head (head h -> kv h//g,
    groups contiguous — the ``init_attention`` layout), so GQA needs no KV
    repetition in HBM.
    """
    b, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    win = jnp.asarray(window, jnp.int32).reshape(1)
    out = _pa.paged_attention_bkgd(qg, k_pages, v_pages,
                                   jnp.asarray(tables, jnp.int32),
                                   jnp.asarray(pos, jnp.int32), win,
                                   interpret=_interpret())
    return out.reshape(b, hq, d)


@jax.jit
def paged_prefill_attention(q, k_pages, v_pages, tables, start, window=0):
    """q: [B, C, Hq, D] — one C-token prefill chunk per slot, row b's query
    c at logical position ``start[b] + c``; k_pages, v_pages:
    [NB, BS, Hkv, D]; tables: [B, MB] int32 block ids (-1 = unassigned);
    start: [B] int32; window: int32 scalar (0 = full; dynamic — gemma3's
    per-layer windows are traced). Returns [B, C, Hq, D]. The chunk's own
    K/V must already be written through the table (the layer writes before
    attending), so causal in-chunk attention reads it from the pool. Q
    heads group per kv head as in ``paged_attention``.
    """
    b, c, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    qg = q.reshape(b, c, hkv, g, d).transpose(0, 2, 1, 3, 4)
    win = jnp.asarray(window, jnp.int32).reshape(1)
    out = _pa.paged_prefill_bkgd(qg, k_pages, v_pages,
                                 jnp.asarray(tables, jnp.int32),
                                 jnp.asarray(start, jnp.int32), win,
                                 interpret=_interpret())
    return out.transpose(0, 2, 1, 3, 4).reshape(b, c, hq, d)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xdt, a_log, B, C, *, chunk: int = 128):
    """xdt: [B, S, H, P]; a_log: [B, S, H]; B, C: [B, S, H, N]."""
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    xf = xdt.transpose(0, 2, 1, 3).reshape(b * h, s, p).astype(jnp.float32)
    af = a_log.transpose(0, 2, 1).reshape(b * h, s, 1).astype(jnp.float32)
    bf = B.transpose(0, 2, 1, 3).reshape(b * h, s, n).astype(jnp.float32)
    cf = C.transpose(0, 2, 1, 3).reshape(b * h, s, n).astype(jnp.float32)
    q = chunk
    while s % q != 0:
        q //= 2
    y = _ssd.ssd_scan_bhsp(xf, af, bf, cf, chunk=q, interpret=_interpret())
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def grouped_matmul(x, w, valid_rows=None, *, bm: int = 128, bn: int = 128,
                   bk: int = 128):
    """x: [G, C, K]; w: [G, K, N]; valid_rows: [G] int32 or None."""
    g, c, k = x.shape
    n = w.shape[-1]
    bm = _shrink(c, bm)
    bn = _shrink(n, bn)
    bk2 = _shrink(k, bk)
    out = _gmm.grouped_matmul(x, w, valid_rows, bm=bm, bn=bn, bk=bk2,
                              interpret=_interpret())
    if valid_rows is not None:
        mask = jnp.arange(c)[None, :] < valid_rows[:, None]
        out = out * mask[..., None].astype(out.dtype)
    return out


def _shrink(dim: int, blk: int) -> int:
    blk = min(blk, dim)
    while dim % blk != 0:
        blk //= 2
    return max(blk, 1)
