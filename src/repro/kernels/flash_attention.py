"""Blocked online-softmax attention (flash attention) for TPU.

TPU adaptation (vs. the CUDA original): the grid's innermost dimension walks
K/V blocks sequentially — Pallas TPU executes grid steps in order on one
core, so the running (m, l, acc) softmax state lives in VMEM scratch and
persists across K-blocks (no atomics / shared-memory tricks needed). Block
shapes are MXU-aligned (seq blocks multiples of 128, full head_dim per
block); the working set per step is q(bq x D) + k,v(bk x D) + acc(bq x D)
floats — sized to sit comfortably in the ~16 MB VMEM.

Supports causal masking, sliding windows (gemma3 local layers) and GQA (KV
block index maps q-head -> kv-head, no KV repetition in HBM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # whole-block skip conditions (causal / out-of-window)
    run = jnp.bool_(True)
    if causal:
        run &= ki * bk <= qi * bq + bq - 1
    if window:
        run &= (ki + 1) * bk - 1 > qi * bq - window

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        m_safe = jnp.where(m_cur <= NEG_INF / 2, 0.0, m_cur)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp(m_prev - m_safe))
        l_cur = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[:, 0] = m_cur
        l_ref[:, 0] = l_cur

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = True):
    """q: [BHq, S, D]; k, v: [BHkv, S, D] — heads flattened into dim 0.

    Returns [BHq, S, D]. GQA handled via the KV index map (group = BHq/BHkv).
    """
    bh, s, d = q.shape
    bh_kv = k.shape[0]
    group = bh // bh_kv
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
