"""Mamba2 SSD chunked scan kernel for TPU.

The SSD block decomposition (arXiv:2405.21060 §6) maps naturally onto the
Pallas TPU execution model: the grid's inner dimension walks chunks of the
sequence IN ORDER, so the inter-chunk state S in R^{N x P} is carried in VMEM
scratch between grid steps — the TPU-native replacement for the CUDA
kernel's warp-level state exchange. Per chunk (length Q):

    intra:  Y += ((C B^T) .* L) X        (dual/attention quadratic form, MXU)
    inter:  Y += (C * exp(lc)) S_prev    (read carried state)
    state:  S  = gamma * S_prev + (B * w)^T X

All math in f32; block shapes (Q x N), (Q x P) are MXU-aligned for Q,N,P in
{64,128,256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0]                       # [Q, P] (dt-scaled inputs)
    a = a_ref[0, :, 0]                 # [Q]    (log decay)
    b = b_ref[0]                       # [Q, N]
    c = c_ref[0]                       # [Q, N]

    lc = jnp.cumsum(a)                 # within-chunk cumulative log decay
    l_last = lc[q - 1]

    # intra-chunk dual form
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    diff = lc[:, None] - lc[None, :]
    decay = jnp.exp(jnp.minimum(diff, 0.0))
    idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    m = jnp.where(idx >= jdx, scores * decay, 0.0)
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [Q,P]

    # inter-chunk contribution from carried state
    c_in = c * jnp.exp(lc)[:, None]
    y += jax.lax.dot_general(c_in, state_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: S = gamma * S_prev + sum_j w_j B_j x_j^T
    w = jnp.exp(l_last - lc)                                           # [Q]
    bw = b * w[:, None]
    state_ref[...] = (jnp.exp(l_last) * state_ref[...]
                      + jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_bhsp(xdt, a_log, B, C, *, chunk: int = 128,
                  interpret: bool = True):
    """xdt: [BH, S, P]; a_log: [BH, S, 1]; B, C: [BH, S, N] -> y [BH, S, P].

    Heads flattened into dim 0; the wrapper in ops.py does the transpose.
    """
    bh, s, p = xdt.shape
    n = B.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    kernel = functools.partial(_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda b, c_: (b, c_, 0)),
            pl.BlockSpec((1, q, 1), lambda b, c_: (b, c_, 0)),
            pl.BlockSpec((1, q, n), lambda b, c_: (b, c_, 0)),
            pl.BlockSpec((1, q, n), lambda b, c_: (b, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda b, c_: (b, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, a_log, B, C)
