"""Paged decode attention for TPU: K/V gathered through a block table.

The serving-side decode hot spot: each in-flight request (slot) owns a list
of fixed-size KV blocks (``serve/paged.py``'s ``BlockManager``) instead of a
contiguous ``max_len`` cache row. One query token per slot attends over the
blocks its table names.

Grid: (slot, kv-head, table-column) — one grid cell per (slot, kv-head), the
innermost dimension walking the slot's block table sequentially. Pallas TPU
executes grid steps in order on one core, so the running (m, l, acc) online-
softmax state lives in VMEM scratch and persists across table columns,
exactly like ``kernels/flash_attention.py``. The block table, per-slot
positions, and the sliding window are scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``): the K/V ``BlockSpec`` index maps read the
table to DMA only the blocks the slot actually owns — unassigned entries
(-1 padding) are clamped to block 0 for the DMA and the cell is skipped via
``pl.when`` (online softmax over valid blocks only). GQA costs nothing extra:
the q-head group of each kv head rides along as the block's row dimension.

Two kernels share the scheme: the decode kernel (one query token per slot)
and the prefill kernel (a C-token chunk per slot at contiguous positions,
causal masking inside the chunk) — the latter is what lane-batched chunked
prefill dispatches instead of falling back to the jnp page gather.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, pos_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, bs: int, nt: int,
            g: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]
    # valid blocks only: the table column must be assigned AND start at or
    # before the row's current position.
    run = (tables_ref[b, j] >= 0) & (j * bs <= pos)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)              # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)           # [BS, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (g, bs), 1)
        mask = k_pos <= pos
        win = win_ref[0]
        mask &= (win == 0) | (k_pos > pos - win)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        m_safe = jnp.where(m_cur <= NEG_INF / 2, 0.0, m_cur)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp(m_prev - m_safe))
        l_cur = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[:, 0] = m_cur
        l_ref[:, 0] = l_cur

    @pl.when(j == nt - 1)
    def _finish():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _prefill_kernel(tables_ref, start_ref, win_ref, q_ref, k_ref, v_ref,
                    o_ref, acc_ref, m_ref, l_ref, *, scale: float, bs: int,
                    nt: int, g: int, c: int):
    """Multi-token sibling of ``_kernel``: one grid cell attends a whole
    [C, G] query chunk (C contiguous positions of one slot, every q head of
    one kv head) against one table column, with causal masking *inside* the
    chunk — query offset r // g at logical position start + r // g only sees
    k_pos <= its own position. The (m, l, acc) online-softmax state carries
    [C * G] rows across table columns."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = start_ref[b]
    # valid blocks only: assigned AND starting at or before the chunk's last
    # query position (later blocks hold nothing any query may attend).
    run = (tables_ref[b, j] >= 0) & (j * bs <= start + c - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32).reshape(c * g, -1)   # [CG, D]
        k = k_ref[0, :, 0].astype(jnp.float32)                   # [BS, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = start + jax.lax.broadcasted_iota(jnp.int32, (c * g, bs),
                                                 0) // g
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (c * g, bs), 1)
        mask = k_pos <= q_pos
        win = win_ref[0]
        mask &= (win == 0) | (k_pos > q_pos - win)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        m_safe = jnp.where(m_cur <= NEG_INF / 2, 0.0, m_cur)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp(m_prev - m_safe))
        l_cur = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[:, 0] = m_cur
        l_ref[:, 0] = l_cur

    @pl.when(j == nt - 1)
    def _finish():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        d = acc_ref.shape[-1]
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).reshape(
            c, g, d).astype(o_ref.dtype)


def paged_prefill_bkgd(q, k_pages, v_pages, tables, start, window, *,
                       interpret: bool = True):
    """q: [B, Hkv, C, G, D] (a C-token prefill chunk per slot, q heads
    grouped per kv head); k_pages, v_pages: [NB, BS, Hkv, D]; tables:
    [B, MB] int32 (-1 = unassigned); start: [B] int32 — row b's chunk
    covers contiguous logical positions [start[b], start[b] + C); window:
    [1] int32 (0 = full attention). The chunk's K/V must already be written
    through the table (``layers.paged_kv_write`` runs first), so causal
    in-chunk attention reads it back from the pool like every earlier
    block. Returns [B, Hkv, C, G, D].
    """
    b, hkv, c, g, d = q.shape
    nb, bs = k_pages.shape[:2]
    mb = tables.shape[1]
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_prefill_kernel, scale=scale, bs=bs, nt=mb,
                               g=g, c=c)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, mb),
        in_specs=[
            pl.BlockSpec((1, 1, c, g, d),
                         lambda i, h, j, tables, start, win: (i, h, 0, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda i, h, j, tables, start, win:
                         (jnp.maximum(tables[i, j], 0), 0, h, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda i, h, j, tables, start, win:
                         (jnp.maximum(tables[i, j], 0), 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, g, d),
                               lambda i, h, j, tables, start, win:
                               (i, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c * g, d), jnp.float32),
            pltpu.VMEM((c * g, 1), jnp.float32),
            pltpu.VMEM((c * g, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, c, g, d), q.dtype),
        interpret=interpret,
    )(tables, start, window, q, k_pages, v_pages)


def paged_attention_bkgd(q, k_pages, v_pages, tables, pos, window, *,
                         interpret: bool = True):
    """q: [B, Hkv, G, D] (q heads grouped per kv head); k_pages, v_pages:
    [NB, BS, Hkv, D]; tables: [B, MB] int32 (-1 = unassigned); pos: [B]
    int32; window: [1] int32 (0 = full attention). Returns [B, Hkv, G, D].
    """
    b, hkv, g, d = q.shape
    nb, bs = k_pages.shape[:2]
    mb = tables.shape[1]
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_kernel, scale=scale, bs=bs, nt=mb, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, mb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda i, h, j, tables, pos, win: (i, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda i, h, j, tables, pos, win:
                         (jnp.maximum(tables[i, j], 0), 0, h, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda i, h, j, tables, pos, win:
                         (jnp.maximum(tables[i, j], 0), 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda i, h, j, tables, pos, win: (i, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(tables, pos, window, q, k_pages, v_pages)
