"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, Sq, Hq, D]; k, v: [B, Sk, Hkv, D] -> [B, Sq, Hq, D]."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    sk = k.shape[1]
    q_pos = jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def paged_attention(q, k_pages, v_pages, tables, pos, window=0):
    """Paged decode attention oracle (one query token per slot).

    q: [B, Hq, D]; k_pages, v_pages: [NB, BS, Hkv, D]; tables: [B, MB] int32
    block ids (-1 = unassigned); pos: [B] int32 — row b attends logical
    positions [0, pos[b]] gathered through its block table. -> [B, Hq, D].
    """
    nb, bs, hkv, d = k_pages.shape
    b, hq, _ = q.shape
    safe = jnp.maximum(tables, 0)
    k = k_pages[safe].reshape(b, -1, hkv, d).astype(jnp.float32)
    v = v_pages[safe].reshape(b, -1, hkv, d).astype(jnp.float32)
    k_pos = jnp.arange(k.shape[1])[None, :]
    valid = jnp.repeat(tables >= 0, bs, axis=1) & (k_pos <= pos[:, None])
    if window:
        valid &= k_pos > pos[:, None] - window
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k) / math.sqrt(d)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v)
    return out.reshape(b, hq, d).astype(q.dtype)


def paged_prefill_attention(q, k_pages, v_pages, tables, start, window=0):
    """Paged prefill-chunk attention oracle (C query tokens per slot).

    q: [B, C, Hq, D]; k_pages, v_pages: [NB, BS, Hkv, D]; tables: [B, MB]
    int32 block ids (-1 = unassigned); start: [B] int32 — row b's query c
    sits at logical position ``start[b] + c`` and attends positions
    [0, start[b] + c] gathered through its block table (causal inside the
    chunk). -> [B, C, Hq, D].
    """
    nb, bs, hkv, d = k_pages.shape
    b, c, hq, _ = q.shape
    safe = jnp.maximum(tables, 0)
    k = k_pages[safe].reshape(b, -1, hkv, d).astype(jnp.float32)
    v = v_pages[safe].reshape(b, -1, hkv, d).astype(jnp.float32)
    k_pos = jnp.arange(k.shape[1])[None, None, :]                # [1, 1, K]
    q_pos = (start[:, None] + jnp.arange(c)[None, :])[:, :, None]  # [B, C, 1]
    valid = jnp.repeat(tables >= 0, bs, axis=1)[:, None, :] & (k_pos <= q_pos)
    if window:
        valid &= k_pos > q_pos - window
    g = hq // hkv
    qg = q.reshape(b, c, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bchgd,bkhd->bhgck", qg, k) / math.sqrt(d)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgck,bkhd->bchgd", p, v)
    return out.reshape(b, c, hq, d).astype(q.dtype)


def ssd(xdt, a_log, B, C):
    """Naive sequential SSD recurrence (the semantic ground truth).

    xdt: [B, S, H, P]; a_log: [B, S, H]; B, C: [B, S, H, N] -> [B, S, H, P]
        h_t = exp(a_log_t) * h_{t-1} + B_t (x) xdt_t;   y_t = C_t . h_t
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp
        state = (jnp.exp(a_t)[..., None, None] * state
                 + jnp.einsum("bhn,bhp->bhnp", b_t, x_t))
        y_t = jnp.einsum("bhn,bhnp->bhp", c_t, state)
        return state, y_t

    init = jnp.zeros((b, h, n, p), jnp.float32)
    xs = (xdt.swapaxes(0, 1), a_log.swapaxes(0, 1),
          B.swapaxes(0, 1), C.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, init, xs)
    return ys.swapaxes(0, 1)


def grouped_matmul(x, w, valid_rows=None):
    """x: [G, C, K]; w: [G, K, N] -> [G, C, N]; invalid rows zeroed."""
    out = jnp.einsum("gck,gkn->gcn", x, w)
    if valid_rows is not None:
        c = x.shape[1]
        mask = jnp.arange(c)[None, :] < valid_rows[:, None]
        out = out * mask[..., None].astype(out.dtype)
    return out
