"""Synergy-on-serve: SLO-aware multi-tenant resource allocation.

The paper's core loop — optimistic profiling → per-resource sensitivity
curves → near-optimal online allocation — applied to the serving engine's
scarce resources instead of a training cluster's CPUs and memory:

    training (core/)                 serving (this module)
    ----------------                 ---------------------
    CPU cores per job                KV cache units (blocks / slots)
    DRAM cache GB per job            prefill lanes
    W_j[c, m] sensitivity matrix     W_t[units, K] per request class
    optimistic profiling (§3.1)      2 empirical probes + analytic model
    Synergy-Greedy / OPT (§4)        ``TenantAllocator`` (greedy knees)
    GPU-proportional fairness floor  weight-proportional unit floor

A ``Tenant`` carries an identity, a weight, and a latency SLO (in decode
steps and/or wall seconds). ``ServeRequest.tenant`` tags every request with
its tenant id; the ``TenantRegistry`` resolves tags to tenants and computes
per-request *SLO slack* — the engine's scheduling currency:

    slack(r, now) = (arrival + slo_steps) - (now + tokens still owed)

Three mechanisms consume it (wired through ``ServeEngine``):

  * **Admission** (``SLOSlack`` policy): the ready queue is ordered by
    slack, smallest first, instead of FCFS/SJF — a latency-sensitive
    request jumps a batch tenant's backlog.
  * **Preemption**: under block-pool pressure the victim is the active
    request with the LARGEST slack (a batch request without an SLO has
    infinite slack), not the most recently admitted one.
  * **Horizon choice**: the per-boundary decode horizon shrinks toward the
    smallest waiting slack, so the scheduler's next intervention lands
    before a queued tenant's deadline pressure, and is capped at the
    allocator's per-tenant horizon knee.

The **optimistic serve profiler** builds each request class's sensitivity
to its serve resources as a ``core.sensitivity.SensitivityMatrix`` with
cache units on the CPU axis and decode-horizon K on the memory axis. The
steady-state throughput model (the serving mirror of ``sensitivity.
throughput``'s max-of-service-times) is

    n(U)       = min(concurrency, U // units_per_req)   admissible rows
    rate(U, K) = n * K / (t_fixed + n * K * t_tok)      tokens / second

— increasing and knee-shaped in both axes: beyond enough units to admit
the offered concurrency, more cache buys nothing; beyond a few horizon
steps the per-dispatch overhead ``t_fixed`` is amortized. The model is
calibrated from TWO empirical probes of the real engine (full allocation
at K=1 and K=K_max — probes only along one edge of the grid, exactly the
paper's optimistic-profiling trick) or from caller-supplied constants.

``TenantAllocator.plan`` turns the per-tenant matrices into budgets with
the greedy near-optimal machinery (``core.opt.greedy_allocate``): each
tenant's weight-proportional share is the fairness floor, knees cap what a
tenant can usefully consume (an insensitive tenant donates its surplus),
and the watermark reserve is split by marginal growth sensitivity — stolen
from tenants whose curve is flat at their budget. The resulting
``TenantAllocation`` drives admission budgets, per-tenant watermark
headroom, prefill-lane shares, and per-tenant horizon caps.

None of this touches per-request computation: prefill stays exact-length
per request and decode rows are independent, so greedy outputs under
tenant-aware allocation are token-identical to the single-tenant engine
(``launch.serve --verify`` holds for every tenant mix).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.opt import greedy_allocate
from repro.core.policies import Policy
from repro.core.sensitivity import SensitivityMatrix


# ---------------------------------------------------------------------------
# tenants
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Tenant:
    """One tenant: identity, scheduling weight, and latency SLOs.

    ``slo_steps`` is the latency target in decode steps (the engine's
    deterministic clock — drives slack ordering, preemption, and the
    horizon choice); ``slo_s`` is the wall-clock target (seconds — only
    scored in the stats, never scheduled on: wall time is machine-speed
    dependent). Either may be None (no target on that clock).
    """
    tenant_id: str
    weight: float = 1.0
    slo_steps: Optional[float] = None
    slo_s: Optional[float] = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.tenant_id!r}: weight must be > 0")


class TenantRegistry:
    """Tenant lookup + the slack arithmetic every mechanism shares."""

    def __init__(self, tenants: Sequence[Tenant] = ()):
        self._tenants: Dict[str, Tenant] = {}
        for t in tenants:
            self.register(t)

    def register(self, tenant: Tenant) -> Tenant:
        if tenant.tenant_id in self._tenants:
            raise ValueError(f"tenant {tenant.tenant_id!r} already registered")
        self._tenants[tenant.tenant_id] = tenant
        return tenant

    def get(self, tenant_id: str) -> Optional[Tenant]:
        return self._tenants.get(tenant_id)

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._tenants

    def __iter__(self):
        return iter(self._tenants.values())

    def __len__(self) -> int:
        return len(self._tenants)

    @property
    def ids(self) -> List[str]:
        return sorted(self._tenants)

    def slack(self, req, now: float) -> float:
        """SLO slack of ``req`` at engine step ``now``, in decode steps.

        Deadline minus projected finish: a request still owes
        ``max_new_tokens - len(output)`` tokens (~1 per step once
        running). Requests of tenants without a step SLO have infinite
        slack — they order last and preempt first.
        """
        t = self.get(getattr(req, "tenant", None))
        if t is None or t.slo_steps is None:
            return math.inf
        owed = req.max_new_tokens - len(req.output)
        return (req.arrival_time + t.slo_steps) - (now + owed)


class SLOSlack(Policy):
    """Queue ordering by SLO slack, smallest (most urgent) first.

    A serve-side policy in the ``core.policies`` mold: it only ORDERS the
    ready queue (``Policy.order`` tie-breaks on arrival then id); the
    allocator decides amounts — the same policy/mechanism separation the
    paper draws for training jobs.
    """
    name = "slo"

    def __init__(self, registry: TenantRegistry):
        self.registry = registry

    def priority(self, req, now: float) -> float:
        return self.registry.slack(req, now)


# ---------------------------------------------------------------------------
# optimistic serve profiler
# ---------------------------------------------------------------------------
def serve_rate(units: float, k: float, *, units_per_req: int,
               concurrency: int, t_tok: float, t_fixed: float) -> float:
    """Steady-state decode tokens/s of one request class at a cache-unit
    budget and decode horizon (the analytic model the probes calibrate)."""
    if units_per_req <= 0:
        raise ValueError("units_per_req must be >= 1")
    n = min(concurrency, int(units) // units_per_req)
    if n <= 0 or k < 1:
        return 0.0
    return n * k / (t_fixed + n * k * t_tok)


def calibrate(rate_k1: float, rate_kmax: float, n_rows: int,
              k_max: int) -> tuple:
    """(t_tok, t_fixed) from the two edge probes.

    Inverting rate = n·K / (t_fixed + n·K·t_tok):
        1/rate = t_fixed / (n·K) + t_tok
    so two probes at K=1 and K=k_max solve both constants.
    """
    if k_max <= 1:
        raise ValueError("calibration needs k_max > 1")
    if rate_k1 <= 0 or rate_kmax <= 0:
        raise ValueError("probe rates must be positive")
    t_fixed = max(0.0, n_rows * (1.0 / rate_k1 - 1.0 / rate_kmax)
                  * k_max / (k_max - 1))
    t_tok = max(1e-9, 1.0 / rate_k1 - t_fixed / n_rows)
    return t_tok, t_fixed


@dataclass
class ServeClassProfile:
    """One request class's calibrated sensitivity to its serve resources.

    ``matrix`` is a ``core.sensitivity.SensitivityMatrix`` with cache
    units (KV blocks, or slots for the contiguous pool) on the CPU axis
    and decode-horizon K on the memory axis; ``lane_curve`` is the 1-D
    prefill-lane sensitivity (prompts per chunk-round saturates at the
    class's offered concurrency).
    """
    tenant_id: str
    units_per_req: int            # cache units one request needs
    concurrency: int              # offered concurrent requests
    t_tok: float                  # seconds per decode token per row
    t_fixed: float                # per-dispatch overhead seconds
    matrix: SensitivityMatrix = field(repr=False)
    source: str = "analytic"      # where (t_tok, t_fixed) came from:
                                  # "analytic" | "probed" | "measured"

    def lane_curve(self) -> Callable[[float], float]:
        """Prefill-lane sensitivity: a class can fill at most
        ``concurrency`` lanes per chunk-round — flat beyond that knee."""
        return lambda p: float(min(p, self.concurrency))


def profile_class(tenant_id: str, *, units_per_req: int, concurrency: int,
                  total_units: int, max_k: int = 8,
                  t_tok: float = 2e-3, t_fixed: float = 6e-3,
                  probe: Optional[Callable[[int], float]] = None,
                  store=None, arch: Optional[str] = None,
                  backend: Optional[str] = None) -> ServeClassProfile:
    """Build one class's sensitivity profile, optimistically.

    ``probe(k) -> tokens/s`` measures the REAL engine at full allocation
    with horizon ``k``; two calls (k=1 and k=max_k) calibrate the analytic
    model that fills the whole [units x K] grid — |units|·|K| runs of
    exhaustive profiling collapse to 2, the §3.1 trick. Without a probe
    the caller-supplied constants are used directly (cheap CLI default;
    units-axis knees are exact either way because the units axis is pure
    admission arithmetic).

    ``store`` (an ``obs.ProfileStore``, with ``arch`` naming the model and
    ``backend`` the cache kind) closes the measurement loop: when the
    store's decode records for (arch, backend) support a rate fit, the
    MEASURED (t_tok, t_fixed) replace the analytic defaults — the knees
    then come from real dispatch costs (``launch.serve --profile-store``).
    A probe still wins (it measured THIS workload), and a store without a
    usable fit falls back to the analytic constants, so the path is safe
    to leave flag-gated on.
    """
    units_per_req = max(int(units_per_req), 1)
    concurrency = max(int(concurrency), 1)
    probes, probe_s = 0, 0.0
    source = "analytic"
    if probe is not None:
        t0 = time.perf_counter()
        r1 = probe(1)
        rk = probe(max_k)
        probe_s = time.perf_counter() - t0
        probes = 2
        n_rows = min(concurrency, total_units // units_per_req)
        t_tok, t_fixed = calibrate(r1, rk, max(n_rows, 1), max_k)
        source = "probed"
    elif store is not None and arch is not None:
        fit = store.rate_fit(arch, backend)
        if fit is not None:
            t_tok, t_fixed = fit
            source = "measured"

    # unit grid: one requests's footprint up to the pool, plus the pool
    # itself so the proportional floor always lands on the grid.
    unit_points = sorted({min(u, total_units) for u in
                          [units_per_req * i
                           for i in range(1, concurrency + 1)]
                          } | {total_units})
    k_points = [k for k in (1, 2, 4, 8, 16, 32) if k <= max_k] or [1]
    if k_points[-1] != max_k:
        k_points.append(max_k)
    W = np.zeros((len(unit_points), len(k_points)))
    for ui, u in enumerate(unit_points):
        for ki, k in enumerate(k_points):
            W[ui, ki] = serve_rate(u, k, units_per_req=units_per_req,
                                   concurrency=concurrency, t_tok=t_tok,
                                   t_fixed=t_fixed)
    matrix = SensitivityMatrix(np.asarray(unit_points, float),
                               np.asarray(k_points, float), W, gpus=1,
                               profile_probes=probes,
                               profile_seconds=probe_s)
    return ServeClassProfile(tenant_id=tenant_id,
                             units_per_req=units_per_req,
                             concurrency=concurrency, t_tok=t_tok,
                             t_fixed=t_fixed, matrix=matrix, source=source)


def profiles_from_requests(registry: TenantRegistry, requests, *,
                           total_units: int, units_for=None, max_k: int = 8,
                           t_tok: float = 2e-3, t_fixed: float = 6e-3,
                           probe=None, store=None,
                           arch: Optional[str] = None,
                           backend: Optional[str] = None,
                           ) -> Dict[str, ServeClassProfile]:
    """One profile per tenant, its class shape read off its request mix.

    ``units_for(req) -> int`` maps a request to its cache-unit footprint
    (paged: ``blocks_for(prompt + max_new)``; contiguous: 1 slot).
    ``probe(tenant_id, k) -> tokens/s`` optionally runs the real engine.
    ``store``/``arch``/``backend`` feed measured rate constants from an
    ``obs.ProfileStore`` when no probe is given (see ``profile_class``).
    """
    if units_for is None:
        units_for = lambda r: 1
    profiles = {}
    for t in registry:
        rs = [r for r in requests if r.tenant == t.tenant_id]
        if not rs:
            continue
        upr = max(1, int(round(float(np.mean([units_for(r) for r in rs])))))
        profiles[t.tenant_id] = profile_class(
            t.tenant_id, units_per_req=upr, concurrency=len(rs),
            total_units=total_units, max_k=max_k, t_tok=t_tok,
            t_fixed=t_fixed,
            probe=(lambda k, tid=t.tenant_id: probe(tid, k)) if probe
            else None, store=store, arch=arch, backend=backend)
    return profiles


# ---------------------------------------------------------------------------
# the online allocator
# ---------------------------------------------------------------------------
@dataclass
class TenantShare:
    """One tenant's allocated serve resources."""
    tenant_id: str
    units: int                    # cache-unit budget (blocks / slots)
    k_cap: int                    # horizon knee at this unit budget
    lanes: int                    # prefill-lane share under contention
    headroom: int                 # watermark reserve blocks owned
    knee_rate: float = 0.0        # modeled tokens/s at the budget


@dataclass
class TenantAllocation:
    """Per-tenant budgets the engine enforces online.

    Budgets are allocation guidance, not hard partitions: a tenant's
    FIRST request always admits (no deadlock on an undersized budget),
    and units left on the table by one tenant are usable by others once
    their budgets are exhausted only via preemption pressure — the same
    work-conserving discipline as Synergy's cluster allocations.
    """
    shares: Dict[str, TenantShare]
    total_units: int
    max_k: int
    #: arithmetic of the most recent ``admissible`` check (held / need /
    #: budget), read by the scheduler's ``budget_skip`` trace event
    last_decision: Optional[Dict[str, int]] = None

    def share(self, tenant_id: str) -> Optional[TenantShare]:
        return self.shares.get(tenant_id)

    def footprint(self, req, pool) -> int:
        """One request's FULL eventual cache-unit footprint — prompt plus
        generation budget, the same unit the profiler's ``units_per_req``
        measures (paged: blocks; contiguous: one slot)."""
        return (pool.blocks_for(len(req.prompt) + req.max_new_tokens)
                if hasattr(pool, "blocks_for") else 1)

    def units_used(self, tenant_id: str, active, pool) -> int:
        """Cache units the tenant's active requests have COMMITTED: each
        one's full eventual footprint, not just the blocks it owns right
        now — admission reserves decode-growth room, so a budget binds
        when the tenant floods the pool, not only after it has grown."""
        return sum(self.footprint(r, pool)
                   for r in active.values() if r.tenant == tenant_id)

    def admissible(self, req, active, pool) -> bool:
        """Budget check at admission: the request's footprint fits the
        tenant's unit budget. A tenant with nothing active always passes
        (budgets guide, they must never starve).

        ``last_decision`` keeps the arithmetic of the MOST RECENT check —
        (units held, request footprint, budget) — so the scheduler's
        ``budget_skip`` trace event can say why a request was skipped, not
        just that it was."""
        share = self.shares.get(req.tenant)
        if share is None:
            self.last_decision = None
            return True
        used = self.units_used(req.tenant, active, pool)
        need = self.footprint(req, pool)
        self.last_decision = {"held": used, "need": need,
                              "budget": share.units}
        if used == 0:
            return True
        return used + need <= share.units

    def reserves(self) -> Dict[str, int]:
        """Per-tenant watermark headroom (blocks) — installed on the
        ``BlockManager`` so a tenant admitting only has to keep the OTHER
        tenants' headroom free."""
        return {tid: s.headroom for tid, s in self.shares.items()}

    def rescaled_reserves(self, new_total: int) -> Dict[str, int]:
        """Headroom re-fit to a pool whose capacity changed mid-run (a
        ``pool_shrink``/``pool_restore`` fault or an elastic reshape): each
        tenant's reserve scales by ``new_total / total_units`` with
        largest-remainder rounding, so the proportions the allocator
        planned survive the shrink and the summed reserve never exceeds
        the scaled original — reserves pinned to the old capacity would
        deadlock admission on a pool that no longer has that many blocks.

        Ties in the rounding remainder break on the tenant id, so the
        result is a pure function of (shares, new_total) — reshapes replay
        deterministically regardless of dict insertion order. As a final
        backstop the summed reserve is clamped to the new capacity
        (trimming the largest reserves first): a hand-built allocation
        whose headroom exceeds the pool must not wedge admission."""
        if self.total_units <= 0:
            return self.reserves()
        frac = max(0.0, min(1.0, new_total / self.total_units))
        raw = {tid: s.headroom * frac for tid, s in self.shares.items()}
        out = {tid: int(v) for tid, v in raw.items()}
        owed = int(round(sum(raw.values()))) - sum(out.values())
        for tid in sorted(raw, key=lambda t: (out[t] - raw[t], t)
                          )[:max(owed, 0)]:
            out[tid] += 1
        over = sum(out.values()) - max(int(new_total), 0)
        while over > 0:
            tid = max(sorted(out), key=lambda t: out[t])
            if out[tid] <= 0:
                break
            out[tid] -= 1
            over -= 1
        return out

    def k_cap_for(self, tenant_ids) -> int:
        """Horizon cap for a boundary whose active rows belong to
        ``tenant_ids``: the LARGEST knee among them (a longer horizon
        cannot hurt a tenant whose curve flattened earlier, and cutting
        to the smallest knee would tax every co-resident tenant)."""
        caps = [self.shares[t].k_cap for t in tenant_ids
                if t in self.shares]
        return max(caps) if caps else self.max_k

    def lane_share(self, tenant_id: str) -> int:
        share = self.shares.get(tenant_id)
        return share.lanes if share is not None else 1


class TenantAllocator:
    """Sensitivity curves -> per-tenant budgets, greedily near-optimal.

    The serve-side Synergy-Greedy: the weight-proportional unit share is
    each tenant's fairness floor (never allocate less *throughput* than
    proportional — §4.2), knees cap useful consumption, and
    ``core.opt.greedy_allocate`` hands out the pool by weighted marginal
    gain, so an insensitive tenant's surplus flows to whoever's curve is
    still climbing.
    """

    def __init__(self, registry: TenantRegistry,
                 profiles: Dict[str, ServeClassProfile]):
        self.registry = registry
        self.profiles = profiles
        missing = [t.tenant_id for t in registry
                   if t.tenant_id not in profiles]
        if missing:
            raise ValueError(f"no serve profile for tenants {missing}")

    def plan(self, total_units: int, *, total_lanes: int = 4,
             max_k: int = 8, watermark_units: int = 0,
             knee: float = 0.95) -> TenantAllocation:
        tenants = sorted(self.registry, key=lambda t: t.tenant_id)
        profs = [self.profiles[t.tenant_id] for t in tenants]
        weights = [t.weight for t in tenants]

        # floors: one request's footprint each (the no-starvation floor);
        # the fairness floor enters through each curve's knee target below.
        floors = [min(p.units_per_req,
                      total_units // max(len(tenants), 1)) for p in profs]
        quantum = max(1, min(p.units_per_req for p in profs))
        curves = [p.matrix.curve(float(max_k)) for p in profs]
        units = greedy_allocate(curves, float(total_units), weights=weights,
                                floors=[float(f) for f in floors],
                                quantum=float(quantum))
        units = [int(u) for u in units]

        # per-tenant horizon knee at the settled budget
        k_caps = [int(p.matrix.best_second_axis(u, knee))
                  for p, u in zip(profs, units)]

        # prefill lanes: same greedy over the 1-D lane curves, everyone
        # keeps at least one lane (lanes are time-shared, not partitioned).
        lane_floor = [1.0] * len(tenants)
        if total_lanes >= len(tenants):
            lanes = greedy_allocate([p.lane_curve() for p in profs],
                                    float(total_lanes), weights=weights,
                                    floors=lane_floor, quantum=1.0)
        else:
            lanes = [1.0] * len(tenants)
        lanes = [max(1, int(l)) for l in lanes]

        # watermark headroom by marginal growth sensitivity at the budget:
        # a tenant whose curve is flat there (insensitive) donates its
        # reserve to tenants still climbing. Fallback to weight when every
        # curve is flat. Largest-remainder rounding keeps the sum exact.
        sens = [max(0.0, c(u + quantum) - c(max(u - quantum, 0)))
                for c, u in zip(curves, units)]
        raw = [w * s for w, s in zip(weights, sens)]
        if sum(raw) <= 0:
            raw = weights[:]
        scale = watermark_units / sum(raw) if sum(raw) else 0.0
        head = [int(r * scale) for r in raw]
        rem = watermark_units - sum(head)
        order = sorted(range(len(raw)),
                       key=lambda i: -(raw[i] * scale - head[i]))
        for i in range(rem):
            head[order[i % len(head)]] += 1

        shares = {}
        for i, t in enumerate(tenants):
            shares[t.tenant_id] = TenantShare(
                tenant_id=t.tenant_id, units=units[i], k_cap=k_caps[i],
                lanes=lanes[i], headroom=head[i],
                knee_rate=float(curves[i](units[i])))
        return TenantAllocation(shares=shares, total_units=total_units,
                                max_k=max_k)


def plan_allocation(registry: TenantRegistry,
                    profiles: Dict[str, ServeClassProfile],
                    total_units: int, **kw) -> TenantAllocation:
    """Convenience: ``TenantAllocator(registry, profiles).plan(...)``."""
    return TenantAllocator(registry, profiles).plan(total_units, **kw)
