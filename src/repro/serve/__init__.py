"""Serving subsystem: pooled cache, continuous batching, sharded decode.

See serve/README.md for the architecture.
"""
from repro.serve.cache import CachePool
from repro.serve.engine import (CACHE_BACKENDS, Request, ServeEngine,
                                ServeStats, serve_step_fn)
from repro.serve.paged import BlockManager
from repro.serve.scheduler import (SERVE_POLICIES, ContinuousScheduler,
                                   ServeRequest)
from repro.serve.sharded import (ServeSharding, make_serve_sharding,
                                 sharded_engine)

__all__ = [
    "BlockManager", "CACHE_BACKENDS", "CachePool", "ContinuousScheduler",
    "Request", "ServeEngine", "ServeRequest", "ServeSharding", "ServeStats",
    "SERVE_POLICIES", "make_serve_sharding", "serve_step_fn",
    "sharded_engine",
]
