"""Serving subsystem: pooled cache, continuous batching, sharded decode.

See serve/README.md for the architecture.
"""
from repro.serve.cache import CachePool
from repro.serve.chaos import (FAULT_KINDS, Fault, FaultInjector,
                               FaultSchedule)
from repro.serve.elastic import ElasticController, ScalePlan
from repro.serve.engine import (CACHE_BACKENDS, Request, ServeEngine,
                                ServeStats, serve_step_fn)
from repro.serve.paged import BlockManager
from repro.serve.replay import ReplayResult, philly_requests, run_replay
from repro.serve.scheduler import (SERVE_POLICIES, ContinuousScheduler,
                                   ServeRequest)
from repro.serve.sharded import (ServeSharding, make_serve_sharding,
                                 sharded_engine)
from repro.serve.tenant import (SLOSlack, ServeClassProfile, Tenant,
                                TenantAllocation, TenantAllocator,
                                TenantRegistry, TenantShare, plan_allocation,
                                profile_class, profiles_from_requests)

__all__ = [
    "BlockManager", "CACHE_BACKENDS", "CachePool", "ContinuousScheduler",
    "ElasticController", "FAULT_KINDS", "Fault", "FaultInjector",
    "FaultSchedule", "ReplayResult", "Request", "ScalePlan",
    "ServeClassProfile", "ServeEngine",
    "ServeRequest", "ServeSharding", "ServeStats", "SERVE_POLICIES",
    "SLOSlack", "Tenant", "TenantAllocation", "TenantAllocator",
    "TenantRegistry", "TenantShare", "make_serve_sharding",
    "philly_requests", "plan_allocation", "profile_class",
    "profiles_from_requests", "run_replay", "serve_step_fn",
    "sharded_engine",
]
