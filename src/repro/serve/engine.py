"""Serving engine: prefill + decode over the unified model API.

The engine sits on top of the serve subsystem's cache mechanisms:

  * ``cache.CachePool``   — one padded cache buffer, per-slot alloc/free
    (the ``contiguous`` backend: every request owns a full max_len row).
  * ``paged.BlockManager`` — one block-pool buffer, per-request block tables
    (the ``paged`` backend: a request owns ceil(len / block_size) blocks),
    optionally with ref-counted content-hashed prefix caching.
  * ``scheduler.ContinuousScheduler`` — admission + per-step join/evict,
    FCFS/SJF queue ordering; paged pools admit by free *blocks*; admitted
    requests pass through the scheduler's prefill queue.

Every mode is the same engine loop. *Static* batching is the degenerate
scheduler configuration (all requests arrive at step 0 into a pool with one
slot per request, so there is exactly one admission round and no mid-flight
join/evict); *continuous* batching bounds the pool and lets the scheduler
join/evict per step. TP/DP-sharded decode is the same loop again with a
``sharded.ServeSharding`` plan installed (see serve/sharded.py).

Prefill (contiguous): attention-family models (dense / vlm / moe) run ONE
full forward pass capturing the per-layer K/V via ``return_cache``;
recurrent families (ssm / hybrid / encdec) scan decode steps. Prefill is
per-request at the exact prompt length — no cross-request padding — so a
request's output never depends on what it was batched with, which is what
makes continuous and static batching produce identical per-request outputs.

Prefill (paged): prompts prefill in ``block_size`` chunks through each
request's block table, and chunks from up to ``prefill_lanes`` joining
requests pack into ONE jitted ``[P, block_size]`` dispatch per chunk-round
(padded lanes masked) — admitting N requests costs O(chunk-rounds)
dispatches instead of O(N x chunks). Lanes never interact: each lane writes
through its own table, pad positions write nothing, and MoE lanes carry
per-lane expert counts and per-lane routing capacity so batched chunked
routing equals each request's solo one-pass routing. With the prefix cache
on, a lane starts at its first non-cached block and skips the compute for
shared prompt blocks entirely.

Decode: one jitted step over the live slots with a per-row ``pos`` vector.
The paged backend *compacts* the decode batch to the active slots (padded
to a power-of-two bucket) — the cache is addressed through block tables, so
compaction is free. The contiguous backend reuses the same live-slot
compaction via a jitted gather-decode-scatter over the pool's batch axes
(single-device; the sharded pool keeps full-width decode). The saved work
is reported as ``decode_rows_saved``.

Token selection: greedy by default (the exactness/verify path). With
``temperature > 0`` each slot samples on its own RNG lane —
``jax.random.fold_in`` on the slot id and the decode step — optionally
top-k-truncated, so lanes never interact across slots.
"""
from __future__ import annotations

import contextlib
import functools
import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.api import Model, build_model
from repro.serve.cache import CachePool
from repro.serve.paged import BlockManager
from repro.serve.scheduler import ContinuousScheduler, ServeRequest

#: back-compat alias — the original single-file engine exported ``Request``
Request = ServeRequest

_ATTN_PREFILL_FAMILIES = ("dense", "vlm", "moe")
CACHE_BACKENDS = ("contiguous", "paged")


def _bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n (capped): the compacted decode widths, so
    a bounded number of XLA programs covers every live-slot count."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


@dataclass
class ServeStats:
    n_requests: int
    new_tokens: int
    steps: int
    wall_s: float
    tokens_per_s: float
    slot_utilization: float           # mean active/n_slots over decode steps
    mean_latency_steps: float
    p95_latency_steps: float
    mean_latency_s: float
    max_active: int = 0               # peak concurrently-decoding requests
    decode_rows_saved: float = 0.0    # live-slot compaction: fraction of
                                      # pool rows never decoded
    preemptions: int = 0              # paged: requests bounced on pool
                                      # pressure (regenerated exactly)
    block_report: Optional[dict] = field(default=None)
    # -- phase split + dispatch accounting ------------------------------------
    prefill_s: float = 0.0            # wall seconds inside prefill dispatch
    decode_s: float = 0.0             # wall seconds inside decode dispatch
    prefill_dispatches: int = 0       # jitted prefill calls (paged: one per
                                      # chunk-round across ALL joining lanes)
    decode_dispatches: int = 0        # jitted decode steps
    # -- prefix cache ---------------------------------------------------------
    prefix_blocks_total: int = 0      # prompt blocks allocated (paged)
    prefix_blocks_hit: int = 0        # of those, served from the cache
    prefix_hit_rate: float = 0.0


@dataclass
class _PrefillLane:
    """One live lane of the batched paged prefill: a joining request, its
    chunk cursor (starting past any prefix-cache hits), and its carried
    cross-chunk state (MoE expert counts; None for dense/vlm)."""
    req: ServeRequest
    prompt: np.ndarray
    ptr: int
    cap_row: int
    state: Optional[np.ndarray]


class ServeEngine:
    """Serving engine for any architecture family.

    ``n_slots=None`` (default) sizes the pool to the request set at each
    ``run``/``generate`` call — classic static batching. A fixed ``n_slots``
    bounds the pool and turns on continuous batching: the scheduler queues
    the overflow and joins/evicts requests per decode step.

    ``cache="paged"`` (attention families) swaps the per-slot max_len rows
    for the block-pool cache: admission becomes block-granular (a request
    costs blocks proportional to its length), prefill is chunked and
    lane-batched across joining requests (``prefill_lanes``), shared prompt
    prefixes hit the content-addressed block cache (``prefix_cache``), and
    decode compacts to the live slots. Outputs stay token-identical to
    contiguous.
    """

    def __init__(self, cfg: ArchConfig, params=None, max_len: int = 256,
                 rng=None, n_slots: Optional[int] = None,
                 policy: str = "fcfs", sharding=None,
                 cache: str = "contiguous", block_size: int = 16,
                 n_blocks: Optional[int] = None, watermark: float = 0.05,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, prefill_lanes: int = 4,
                 prefix_cache: bool = True):
        if cache not in CACHE_BACKENDS:
            raise ValueError(f"unknown cache backend {cache!r}; "
                             f"known: {CACHE_BACKENDS}")
        if cache == "paged":
            if cfg.family not in _ATTN_PREFILL_FAMILIES:
                raise ValueError(
                    f"cache='paged' needs an attention family "
                    f"(got {cfg.family!r}: recurrent state is O(1))")
            cfg = cfg.replace(decode_attention="paged")
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.max_len = max_len
        self.n_slots = n_slots
        self.policy = policy
        self.sharding = sharding
        self.cache_kind = cache
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.watermark = watermark
        self.prefill_lanes = max(int(prefill_lanes), 1)
        self.prefix_cache = bool(prefix_cache)
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._sample_key = jax.random.key(sample_seed)
        self._sampler = None
        self._decode_compact = None
        rng = rng if rng is not None else jax.random.key(0)
        with self._rules():
            self.params = (params if params is not None
                           else self.model.init(rng))
        if sharding is not None:
            self.params = jax.device_put(self.params, sharding.param_sharding)
        if cache == "paged":
            mod, mcfg = self.model.module, self.cfg

            def paged_step(params, buffers, tokens, pos, tables):
                return mod.paged_decode_step(mcfg, params, buffers, tokens,
                                             pos, tables)
            if sharding is not None:
                # tokens/pos/tables ride replicated: the compacted decode
                # width varies per step, and they are tiny next to the pool.
                self._decode = jax.jit(
                    paged_step,
                    in_shardings=(sharding.param_sharding,
                                  sharding.cache_sharding, None, None, None),
                    out_shardings=(None, sharding.cache_sharding))
            else:
                self._decode = jax.jit(paged_step)
            self._prefill = self._paged_prefill_fn()
        else:
            if sharding is not None:
                self._decode = jax.jit(
                    self.model.decode_step,
                    in_shardings=(sharding.param_sharding,
                                  sharding.cache_sharding,
                                  sharding.token_sharding,
                                  sharding.pos_sharding),
                    out_shardings=(None, sharding.cache_sharding))
            else:
                self._decode = jax.jit(self.model.decode_step)
                self._decode_compact = self._decode_compact_fn()
            self._prefill = jax.jit(self._prefill_fn())

    def _rules(self):
        """Logical-axis rules context (no-op off-mesh / unsharded)."""
        return (self.sharding.rules() if self.sharding is not None
                else contextlib.nullcontext())

    # -- prefill ---------------------------------------------------------------
    def _prefill_fn(self):
        """(params, tokens[B, S]) -> (last logits [B, 1, V], cache pytree)."""
        cfg, model, max_len = self.cfg, self.model, self.max_len

        if cfg.family in _ATTN_PREFILL_FAMILIES:
            def prefill(params, tokens):
                """One-pass attention prefill via the ``return_cache`` hook."""
                logits, (k, v) = model.module.forward(cfg, params, tokens,
                                                      return_cache=True)
                pad = max_len - tokens.shape[1]
                widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                return logits[:, -1:], {"k": jnp.pad(k, widths),
                                        "v": jnp.pad(v, widths)}
            return prefill

        def prefill(params, tokens):
            """Recurrent prefill: scan decode steps (O(1) state per step)."""
            b, s = tokens.shape
            cache = model.init_cache(b, max_len)
            logits0 = jnp.zeros((b, 1, cfg.vocab_size), jnp.dtype(cfg.dtype))

            def body(carry, t):
                cache, _ = carry
                logits, cache = model.decode_step(
                    params, cache, tokens[:, t][:, None], t)
                return (cache, logits), None

            (cache, logits), _ = jax.lax.scan(body, (cache, logits0),
                                              jnp.arange(s))
            return logits, cache
        return prefill

    def _paged_prefill_fn(self):
        """Jitted lane-batched chunk prefill; ``cap`` is static (it sizes
        the MoE dispatch buffers — per-lane effective capacity is the traced
        ``cap_rows``, so one program covers every prompt length)."""
        mod, cfg = self.model.module, self.cfg

        @functools.partial(jax.jit, static_argnames=("cap",))
        def chunk_fn(params, buffers, tokens, starts, n_valid, tables, state,
                     cap_rows, cap):
            return mod.paged_prefill_chunk(cfg, params, buffers, tokens,
                                           starts, tables, state, cap,
                                           n_valid=n_valid,
                                           cap_rows=cap_rows)
        return chunk_fn

    def _decode_compact_fn(self):
        """Jitted gather-decode-scatter: decode only the pool rows in
        ``idx`` (live slots + distinct idle pad rows), writing the updated
        rows back in place — the contiguous mirror of the paged backend's
        free compaction. Rows decode independently, so the gathered rows'
        outputs equal a full-pool decode's."""
        model, max_len = self.model, self.max_len
        probe_a = jax.eval_shape(lambda: model.init_cache(3, max_len))
        probe_b = jax.eval_shape(lambda: model.init_cache(5, max_len))
        from repro.serve.cache import _batch_axis
        axes = jax.tree_util.tree_map(_batch_axis, probe_a, probe_b)

        def fn(params, buffers, toks, pos, idx):
            sub = jax.tree_util.tree_map(
                lambda b, ax: jnp.take(b, idx, axis=ax), buffers, axes)
            logits, new_sub = model.decode_step(params, sub, toks, pos)
            out = jax.tree_util.tree_map(
                lambda b, nb, ax: b.at[(slice(None),) * ax + (idx,)].set(nb),
                buffers, new_sub, axes)
            return logits, out
        return jax.jit(fn)

    # -- token selection (greedy / per-slot RNG lanes) -------------------------
    def _make_sampler(self):
        temp, tk, base = self.temperature, self.top_k, self._sample_key

        @jax.jit
        def sample(logits, slots, step):
            key = jax.random.fold_in(base, step)
            keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(slots)
            scaled = logits.astype(jnp.float32) / temp
            if tk:
                kth = jax.lax.top_k(scaled, tk)[0][..., -1:]
                scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
            return jax.vmap(jax.random.categorical)(keys, scaled)
        return sample

    def _select_tokens(self, logits, slots, step) -> np.ndarray:
        """logits [N, V] -> next tokens [N]. Greedy unless temperature > 0;
        sampling folds (slot id, decode step) into per-slot RNG lanes.
        Prefill call sites pass ``~step`` (the complement lane) so a slot's
        prefill-sampled token and its first decode token — which happen at
        the same scheduler step — never draw on the same key."""
        if self.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        if self._sampler is None:
            self._sampler = self._make_sampler()
        return np.asarray(
            self._sampler(logits, jnp.asarray(slots, jnp.int32),
                          jnp.int32(step)), np.int32)

    # -- the engine loop ---------------------------------------------------------
    def run(self, requests: List[ServeRequest]
            ) -> Tuple[List[ServeRequest], ServeStats]:
        """Serve ``requests`` to completion; returns (requests, stats)."""
        reqs = list(requests)
        n_slots = self.n_slots if self.n_slots else max(len(reqs), 1)
        t0 = time.perf_counter()
        with self._rules():
            if self.cache_kind == "paged":
                counters = self._run_paged(reqs, n_slots)
            else:
                counters = self._run_contiguous(reqs, n_slots)

        wall = time.perf_counter() - t0
        new_tokens = sum(len(r.output) for r in reqs)
        lat_steps = [r.latency_steps for r in reqs
                     if r.latency_steps is not None]
        lat_wall = [r.latency_s for r in reqs if r.latency_s is not None]
        steps = counters["steps"]
        rows_possible = steps * n_slots
        hit, total = counters["prefix_hits"], counters["prefix_total"]
        stats = ServeStats(
            n_requests=len(reqs),
            new_tokens=new_tokens,
            steps=steps,
            wall_s=wall,
            tokens_per_s=new_tokens / wall if wall > 0 else 0.0,
            slot_utilization=counters["util_acc"] / steps if steps else 0.0,
            mean_latency_steps=float(np.mean(lat_steps)) if lat_steps else 0.0,
            p95_latency_steps=(float(np.percentile(lat_steps, 95))
                               if lat_steps else 0.0),
            mean_latency_s=float(np.mean(lat_wall)) if lat_wall else 0.0,
            max_active=counters["max_active"],
            decode_rows_saved=(1.0 - counters["rows_decoded"] / rows_possible
                               if rows_possible else 0.0),
            preemptions=counters["preemptions"],
            block_report=counters["block_report"],
            prefill_s=counters["prefill_s"],
            decode_s=counters["decode_s"],
            prefill_dispatches=counters["prefill_dispatches"],
            decode_dispatches=counters["decode_dispatches"],
            prefix_blocks_total=total,
            prefix_blocks_hit=hit,
            prefix_hit_rate=hit / total if total else 0.0,
        )
        return reqs, stats

    @staticmethod
    def _counters() -> dict:
        return dict(steps=0, util_acc=0.0, max_active=0, rows_decoded=0,
                    preemptions=0, block_report=None, prefill_s=0.0,
                    decode_s=0.0, prefill_dispatches=0, decode_dispatches=0,
                    prefix_hits=0, prefix_total=0)

    def _run_contiguous(self, reqs, n_slots):
        pool = CachePool(self.model, n_slots, self.max_len)
        if self.sharding is not None:
            pool.buffers = jax.device_put(pool.buffers,
                                          self.sharding.cache_sharding)
        sched = ContinuousScheduler(pool, self.policy)
        for i, r in enumerate(reqs):
            r.job_id = i
            sched.submit(r)

        last = np.zeros((n_slots, 1), np.int32)
        pos = np.zeros((n_slots,), np.int32)
        c = self._counters()

        while sched.has_work:
            sched.evict_finished()
            sched.admit()
            admitted = sched.drain_prefill()
            t0 = time.perf_counter()
            for r in admitted:
                tokens = jnp.asarray(
                    np.asarray(r.prompt, np.int32))[None, :]
                logits, row = self._prefill(self.params, tokens)
                c["prefill_dispatches"] += 1
                pool.write(r.slot, row)
                tok = int(self._select_tokens(logits[:, -1], [r.slot],
                                              ~sched.step)[0])
                r.output.append(tok)
                last[r.slot, 0] = tok
                pos[r.slot] = len(r.prompt)
            if admitted:
                c["prefill_s"] += time.perf_counter() - t0
            sched.evict_finished()       # satisfied by prefill alone
            if not sched.active:
                nxt = sched.next_arrival()
                if nxt is None:
                    break
                sched.step = max(sched.step + 1, int(math.ceil(nxt)))
                continue

            # pool.write's eager scatter loses the NamedSharding layout;
            # restore it only on rounds that actually admitted (decode's
            # out_shardings keeps the cache correctly sharded otherwise).
            if self.sharding is not None and admitted:
                pool.buffers = jax.device_put(
                    pool.buffers, self.sharding.cache_sharding)

            # live-slot compaction (single-device): decode only rows with an
            # active tenant, padded to a power-of-two bucket with DISTINCT
            # idle rows — their garbage decodes in place exactly as the
            # full-width step would have, and scatter-back keeps one writer
            # per row.
            act = sorted(sched.active)
            n_act = len(act)
            bc = _bucket(n_act, n_slots)
            t0 = time.perf_counter()
            if self._decode_compact is not None and bc < n_slots:
                idle = [s for s in range(n_slots) if s not in sched.active]
                idx = np.asarray(act + idle[:bc - n_act], np.int32)
                logits, pool.buffers = self._decode_compact(
                    self.params, pool.buffers, jnp.asarray(last[idx]),
                    jnp.asarray(pos[idx]), jnp.asarray(idx))
                rows = np.arange(n_act)           # compacted row order
                c["rows_decoded"] += bc
            else:
                logits, pool.buffers = self._decode(
                    self.params, pool.buffers, jnp.asarray(last),
                    jnp.asarray(pos))
                rows = np.asarray(act)            # slot-indexed rows
                c["rows_decoded"] += n_slots
            c["decode_dispatches"] += 1
            nxt_tok = self._select_tokens(logits[rows, -1, :],
                                          np.asarray(act, np.int32),
                                          sched.step)
            c["decode_s"] += time.perf_counter() - t0
            for i, slot in enumerate(act):
                r = sched.active[slot]
                r.output.append(int(nxt_tok[i]))
                last[slot, 0] = nxt_tok[i]
                pos[slot] += 1
            c["util_acc"] += n_act / n_slots
            c["max_active"] = max(c["max_active"], n_act)
            c["steps"] += 1
            sched.step += 1
        sched.evict_finished()
        return c

    # -- paged loop --------------------------------------------------------------
    def _batched_paged_prefill(self, pool: BlockManager, reqs, step: int,
                               c: dict) -> None:
        """Prefill all joining requests through up to ``prefill_lanes``
        lanes in lockstep chunk-rounds: one jitted ``[P, block_size]``
        dispatch per round covers one chunk of every live lane. A lane
        starts at its request's first non-cached position (prefix hits skip
        both blocks and compute), commits each completed full block to the
        prefix cache, and on its final chunk samples the request's first
        token from its last-valid-position logits; the freed lane is then
        refilled from the queue so long prompts never serialize behind
        short ones."""
        if not reqs:
            return
        bs, mb = pool.block_size, pool.max_blocks
        is_moe = self.cfg.family == "moe"
        cap_static = self.max_len if is_moe else 0
        if is_moe:
            from repro.models.moe import capacity as moe_capacity
        queue = deque(reqs)
        lanes: List[_PrefillLane] = []
        while queue or lanes:
            while queue and len(lanes) < self.prefill_lanes:
                r = queue.popleft()
                prompt = np.asarray(r.prompt, np.int32)
                state = pool.resume_state(r.slot)
                if is_moe and state is None:
                    state = np.asarray(self.model.paged_prefill_state(1))
                lanes.append(_PrefillLane(
                    req=r, prompt=prompt, ptr=pool.cached_tokens(r.slot),
                    cap_row=(moe_capacity(self.cfg, len(prompt))
                             if is_moe else 0),
                    state=state))
            w = _bucket(len(lanes), self.prefill_lanes)
            tokens = np.zeros((w, bs), np.int32)
            starts = np.zeros((w,), np.int32)
            nv = np.zeros((w,), np.int32)
            caps = np.zeros((w,), np.int32)
            tables = np.full((w, mb), -1, np.int32)
            for i, ln in enumerate(lanes):
                n = min(bs, len(ln.prompt) - ln.ptr)
                tokens[i, :n] = ln.prompt[ln.ptr:ln.ptr + n]
                starts[i], nv[i], caps[i] = ln.ptr, n, ln.cap_row
                tables[i] = pool.tables[ln.req.slot]
            state = None
            if is_moe:
                cols = [ln.state for ln in lanes]
                cols += [np.zeros_like(cols[0])] * (w - len(lanes))
                state = jnp.asarray(np.concatenate(cols, axis=1))
            logits, pool.buffers, new_state = self._prefill(
                self.params, pool.buffers, jnp.asarray(tokens),
                jnp.asarray(starts), jnp.asarray(nv), jnp.asarray(tables),
                state, jnp.asarray(caps), cap=cap_static)
            c["prefill_dispatches"] += 1
            if new_state is not None:
                new_state = np.asarray(new_state)
            done_idx: List[int] = []
            live: List[_PrefillLane] = []
            for i, ln in enumerate(lanes):
                n = int(nv[i])
                if new_state is not None:
                    ln.state = new_state[:, i:i + 1]
                if n == bs:        # a full block is final: cacheable
                    pool.commit_block(
                        ln.req.slot, ln.ptr // bs,
                        None if ln.state is None else ln.state.copy())
                ln.ptr += n
                if ln.ptr >= len(ln.prompt):
                    done_idx.append(i)
                else:
                    live.append(ln)
            if done_idx:
                slots = [lanes[i].req.slot for i in done_idx]
                toks = self._select_tokens(
                    logits[np.asarray(done_idx), -1], slots, ~step)
                for t, i in zip(toks, done_idx):
                    lanes[i].req.output.append(int(t))
            lanes = live

    def _ensure_growth(self, sched, pool: BlockManager, pos) -> int:
        """Guarantee a block for every active row's next write position,
        preempting the most recently admitted request on pool pressure.
        Returns the number of preemptions."""
        n = 0
        while True:
            blocked = next((s for s in sorted(sched.active)
                            if not pool.ensure(s, int(pos[s]) + 1)), None)
            if blocked is None:
                return n
            if len(sched.active) == 1:
                raise RuntimeError(
                    "paged KV pool exhausted with a single active request; "
                    "grow n_blocks or lower max_new_tokens")
            victim = max(sched.active.values(),
                         key=lambda r: (r.admitted_at, r.slot))
            sched.preempt(victim)
            n += 1

    def _run_paged(self, reqs, n_slots):
        pool = BlockManager(self.model, n_slots, self.max_len,
                            block_size=self.block_size,
                            n_blocks=self.n_blocks,
                            watermark=self.watermark,
                            prefix_cache=self.prefix_cache)
        if self.sharding is not None:
            pool.buffers = jax.device_put(pool.buffers,
                                          self.sharding.cache_sharding)
        sched = ContinuousScheduler(pool, self.policy)
        for i, r in enumerate(reqs):
            r.job_id = i
            sched.submit(r)

        last = np.zeros((n_slots, 1), np.int32)
        pos = np.zeros((n_slots,), np.int32)
        c = self._counters()
        peak_report = pool.report()

        while sched.has_work:
            sched.evict_finished()
            sched.admit()
            admitted = sched.drain_prefill()
            if admitted:
                t0 = time.perf_counter()
                self._batched_paged_prefill(pool, admitted, sched.step, c)
                c["prefill_s"] += time.perf_counter() - t0
                for r in admitted:
                    last[r.slot, 0] = r.output[-1]
                    pos[r.slot] = len(r.prompt)
                snap = pool.report()     # pool pressure peaks can be
                                         # prefill-only (max_new == 1 runs)
                if snap["used_blocks"] >= peak_report["used_blocks"]:
                    peak_report = snap
            sched.evict_finished()       # satisfied by prefill alone
            if not sched.active:
                nxt = sched.next_arrival()
                if nxt is None:
                    break
                if not admitted and nxt <= sched.step:
                    raise RuntimeError(
                        "paged KV pool cannot admit any waiting request; "
                        "grow n_blocks or lower the watermark")
                sched.step = max(sched.step + 1, int(math.ceil(nxt)))
                continue

            if self.sharding is not None and admitted:
                pool.buffers = jax.device_put(
                    pool.buffers, self.sharding.cache_sharding)
            c["preemptions"] += self._ensure_growth(sched, pool, pos)

            # live-slot compaction: decode only rows with an active tenant,
            # padded to a power-of-two bucket (pad rows carry all -1 tables,
            # write nowhere, and read nothing).
            act = sorted(sched.active)
            bc = _bucket(len(act), n_slots)
            toks = np.zeros((bc, 1), np.int32)
            toks[:len(act)] = last[act]
            p = np.zeros((bc,), np.int32)
            p[:len(act)] = pos[act]
            tables = np.full((bc, pool.max_blocks), -1, np.int32)
            tables[:len(act)] = pool.table_rows(act)

            t0 = time.perf_counter()
            logits, pool.buffers = self._decode(
                self.params, pool.buffers, jnp.asarray(toks),
                jnp.asarray(p), jnp.asarray(tables))
            c["decode_dispatches"] += 1
            nxt_tok = self._select_tokens(logits[:len(act), -1, :],
                                          np.asarray(act, np.int32),
                                          sched.step)
            c["decode_s"] += time.perf_counter() - t0
            for i, slot in enumerate(act):
                r = sched.active[slot]
                r.output.append(int(nxt_tok[i]))
                last[slot, 0] = nxt_tok[i]
                pos[slot] += 1
            c["util_acc"] += len(act) / n_slots
            c["max_active"] = max(c["max_active"], len(act))
            c["rows_decoded"] += bc
            c["steps"] += 1
            sched.step += 1
            snap = pool.report()
            if snap["used_blocks"] >= peak_report["used_blocks"]:
                peak_report = snap          # report the pool at peak pressure
        sched.evict_finished()
        c["block_report"] = peak_report
        c["prefix_hits"] = pool.prefix_blocks_hit
        c["prefix_total"] = pool.prefix_blocks_total
        return c

    def generate(self, requests: List[ServeRequest]) -> List[ServeRequest]:
        """Run a batch of requests to completion; returns them."""
        return self.run(requests)[0]


def serve_step_fn(cfg: ArchConfig):
    """The (params, cache, tokens, pos) -> (logits, cache) step the dry-run
    lowers for decode shapes."""
    model = build_model(cfg)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step
