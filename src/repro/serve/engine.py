"""Batched serving engine: prefill + greedy decode over the unified model API.

Attention-family models prefill with one full forward pass (capturing the
per-layer K/V via ``return_cache``); recurrent families (ssm/hybrid) prefill
by scanning decode steps (their state is O(1), the scan is jit-compiled once).
Static batching: all requests in a batch share a padded prompt buffer — the
serve_step lowered by the dry-run is exactly `engine.decode_step`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.api import Model, build_model


@dataclass
class Request:
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 16
    output: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params=None, max_len: int = 256,
                 rng=None):
        self.cfg = cfg
        self.model = build_model(cfg)
        rng = rng if rng is not None else jax.random.key(0)
        self.params = params if params is not None else self.model.init(rng)
        self.max_len = max_len
        self._decode = jax.jit(self.model.decode_step)

    # -- prefill ---------------------------------------------------------------
    def _prefill_attention(self, tokens: jnp.ndarray):
        """Dense/MoE/VLM: full forward capturing per-layer (k, v)."""
        from repro.models import transformer as T
        b, s = tokens.shape
        logits, caches = T.forward(self.cfg, self.params, tokens,
                                   return_cache=True)
        k, v = caches                              # [L, B, S, kv, hd]
        pad = self.max_len - s
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return logits, {"k": k, "v": v}

    def _prefill_scan(self, tokens: jnp.ndarray):
        """Recurrent prefill: scan decode steps (ssm / hybrid / encdec)."""
        b, s = tokens.shape
        cache = self.model.init_cache(b, self.max_len)

        def body(carry, t):
            cache, _ = carry
            logits, cache = self.model.decode_step(
                self.params, cache, tokens[:, t][:, None], t)
            return (cache, logits), None

        (cache, logits), _ = jax.lax.scan(
            lambda c, t: body(c, t), (cache, jnp.zeros(
                (b, 1, self.cfg.vocab_size), jnp.float32)),
            jnp.arange(s))
        return logits, cache

    def prefill(self, tokens: jnp.ndarray):
        fam = self.cfg.family
        if fam in ("dense", "vlm"):
            return self._prefill_attention(tokens)
        if fam == "moe":
            # MoE shares the dense cache layout; forward has no return_cache
            # hook, so prefill via the scan path.
            return self._prefill_scan(tokens)
        return self._prefill_scan(tokens)

    # -- generation --------------------------------------------------------------
    def generate(self, requests: List[Request]) -> List[Request]:
        """Run a static batch of requests to completion (greedy)."""
        b = len(requests)
        prompt_len = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, prompt_len), np.int32)
        for i, r in enumerate(requests):
            toks[i, prompt_len - len(r.prompt):] = r.prompt     # left-pad
        toks = jnp.asarray(toks)

        logits, cache = self.prefill(toks)
        last = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

        max_new = max(r.max_new_tokens for r in requests)
        pos = prompt_len
        for step in range(max_new):
            for i, r in enumerate(requests):
                if not r.done:
                    r.output.append(int(last[i]))
            if all(r.done for r in requests) or pos >= self.max_len:
                break
            logits, cache = self._decode(self.params, cache,
                                         last[:, None], jnp.int32(pos))
            last = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            pos += 1
        return requests


def serve_step_fn(cfg: ArchConfig):
    """The (params, cache, tokens, pos) -> (logits, cache) step the dry-run
    lowers for decode shapes."""
    model = build_model(cfg)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step
