"""Serving engine: prefill + greedy decode over the unified model API.

The engine sits on top of the serve subsystem's two mechanisms:

  * ``cache.CachePool``   — one padded cache buffer, per-slot alloc/free.
  * ``scheduler.ContinuousScheduler`` — admission by slot availability,
    per-step join/evict, FCFS/SJF queue ordering.

Every mode is the same engine loop. *Static* batching is the degenerate
scheduler configuration (all requests arrive at step 0 into a pool with one
slot per request, so there is exactly one admission round and no mid-flight
join/evict); *continuous* batching bounds the pool and lets the scheduler
join/evict per step. TP/DP-sharded decode is the same loop again with a
``sharded.ServeSharding`` plan installed (see serve/sharded.py).

Prefill: attention-family models (dense / vlm / moe) run ONE full forward
pass capturing the per-layer K/V via ``return_cache``; recurrent families
(ssm / hybrid / encdec) scan decode steps (their state is O(1); the scan is
jit-compiled once). Prefill is per-request at the exact prompt length — no
cross-request padding — so a request's output never depends on what it was
batched with, which is what makes continuous and static batching produce
identical per-request outputs.

Decode: one jitted ``decode_step`` over the whole pool with a per-row ``pos``
vector (each slot at its own sequence position). Inactive slots decode
garbage that is never read and is fully overwritten at the next admission.
"""
from __future__ import annotations

import contextlib
import math
import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.api import Model, build_model
from repro.serve.cache import CachePool
from repro.serve.scheduler import ContinuousScheduler, ServeRequest

#: back-compat alias — the original single-file engine exported ``Request``
Request = ServeRequest

_ATTN_PREFILL_FAMILIES = ("dense", "vlm", "moe")


@dataclass
class ServeStats:
    n_requests: int
    new_tokens: int
    steps: int
    wall_s: float
    tokens_per_s: float
    slot_utilization: float           # mean active/n_slots over decode steps
    mean_latency_steps: float
    p95_latency_steps: float
    mean_latency_s: float


class ServeEngine:
    """Greedy serving engine for any architecture family.

    ``n_slots=None`` (default) sizes the pool to the request set at each
    ``run``/``generate`` call — classic static batching. A fixed ``n_slots``
    bounds the pool and turns on continuous batching: the scheduler queues
    the overflow and joins/evicts requests per decode step.
    """

    def __init__(self, cfg: ArchConfig, params=None, max_len: int = 256,
                 rng=None, n_slots: Optional[int] = None,
                 policy: str = "fcfs", sharding=None):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.max_len = max_len
        self.n_slots = n_slots
        self.policy = policy
        self.sharding = sharding
        rng = rng if rng is not None else jax.random.key(0)
        with self._rules():
            self.params = (params if params is not None
                           else self.model.init(rng))
        if sharding is not None:
            self.params = jax.device_put(self.params, sharding.param_sharding)
            self._decode = jax.jit(
                self.model.decode_step,
                in_shardings=(sharding.param_sharding,
                              sharding.cache_sharding,
                              sharding.token_sharding,
                              sharding.pos_sharding),
                out_shardings=(None, sharding.cache_sharding))
        else:
            self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(self._prefill_fn())

    def _rules(self):
        """Logical-axis rules context (no-op off-mesh / unsharded)."""
        return (self.sharding.rules() if self.sharding is not None
                else contextlib.nullcontext())

    # -- prefill ---------------------------------------------------------------
    def _prefill_fn(self):
        """(params, tokens[B, S]) -> (last logits [B, 1, V], cache pytree)."""
        cfg, model, max_len = self.cfg, self.model, self.max_len

        if cfg.family in _ATTN_PREFILL_FAMILIES:
            def prefill(params, tokens):
                """One-pass attention prefill via the ``return_cache`` hook."""
                logits, (k, v) = model.module.forward(cfg, params, tokens,
                                                      return_cache=True)
                pad = max_len - tokens.shape[1]
                widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                return logits[:, -1:], {"k": jnp.pad(k, widths),
                                        "v": jnp.pad(v, widths)}
            return prefill

        def prefill(params, tokens):
            """Recurrent prefill: scan decode steps (O(1) state per step)."""
            b, s = tokens.shape
            cache = model.init_cache(b, max_len)
            logits0 = jnp.zeros((b, 1, cfg.vocab_size), jnp.dtype(cfg.dtype))

            def body(carry, t):
                cache, _ = carry
                logits, cache = model.decode_step(
                    params, cache, tokens[:, t][:, None], t)
                return (cache, logits), None

            (cache, logits), _ = jax.lax.scan(body, (cache, logits0),
                                              jnp.arange(s))
            return logits, cache
        return prefill

    # -- the engine loop ---------------------------------------------------------
    def run(self, requests: List[ServeRequest]
            ) -> Tuple[List[ServeRequest], ServeStats]:
        """Serve ``requests`` to completion; returns (requests, stats)."""
        reqs = list(requests)
        n_slots = self.n_slots if self.n_slots else max(len(reqs), 1)
        t0 = time.perf_counter()
        with self._rules():
            pool = CachePool(self.model, n_slots, self.max_len)
            if self.sharding is not None:
                pool.buffers = jax.device_put(pool.buffers,
                                              self.sharding.cache_sharding)
            sched = ContinuousScheduler(pool, self.policy)
            for i, r in enumerate(reqs):
                r.job_id = i
                sched.submit(r)

            last = np.zeros((n_slots, 1), np.int32)
            pos = np.zeros((n_slots,), np.int32)
            util_acc, steps = 0.0, 0

            while sched.has_work:
                sched.evict_finished()
                admitted = sched.admit()
                for r in admitted:
                    tokens = jnp.asarray(
                        np.asarray(r.prompt, np.int32))[None, :]
                    logits, row = self._prefill(self.params, tokens)
                    pool.write(r.slot, row)
                    tok = int(jnp.argmax(logits[0, -1]))
                    r.output.append(tok)
                    last[r.slot, 0] = tok
                    pos[r.slot] = len(r.prompt)
                sched.evict_finished()       # satisfied by prefill alone
                if not sched.active:
                    nxt = sched.next_arrival()
                    if nxt is None:
                        break
                    sched.step = max(sched.step + 1, int(math.ceil(nxt)))
                    continue

                # pool.write's eager scatter loses the NamedSharding layout;
                # restore it only on rounds that actually admitted (decode's
                # out_shardings keeps the cache correctly sharded otherwise).
                if self.sharding is not None and admitted:
                    pool.buffers = jax.device_put(
                        pool.buffers, self.sharding.cache_sharding)
                logits, pool.buffers = self._decode(
                    self.params, pool.buffers, jnp.asarray(last),
                    jnp.asarray(pos))
                nxt_tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1),
                                     np.int32)
                for slot, r in sched.active.items():
                    r.output.append(int(nxt_tok[slot]))
                    last[slot, 0] = nxt_tok[slot]
                    pos[slot] += 1
                util_acc += len(sched.active) / n_slots
                steps += 1
                sched.step += 1
            sched.evict_finished()

        wall = time.perf_counter() - t0
        new_tokens = sum(len(r.output) for r in reqs)
        lat_steps = [r.latency_steps for r in reqs
                     if r.latency_steps is not None]
        lat_wall = [r.latency_s for r in reqs if r.latency_s is not None]
        stats = ServeStats(
            n_requests=len(reqs),
            new_tokens=new_tokens,
            steps=steps,
            wall_s=wall,
            tokens_per_s=new_tokens / wall if wall > 0 else 0.0,
            slot_utilization=util_acc / steps if steps else 0.0,
            mean_latency_steps=float(np.mean(lat_steps)) if lat_steps else 0.0,
            p95_latency_steps=(float(np.percentile(lat_steps, 95))
                               if lat_steps else 0.0),
            mean_latency_s=float(np.mean(lat_wall)) if lat_wall else 0.0,
        )
        return reqs, stats

    def generate(self, requests: List[ServeRequest]) -> List[ServeRequest]:
        """Run a batch of requests to completion (greedy); returns them."""
        return self.run(requests)[0]


def serve_step_fn(cfg: ArchConfig):
    """The (params, cache, tokens, pos) -> (logits, cache) step the dry-run
    lowers for decode shapes."""
    model = build_model(cfg)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step
