"""Serving engine: prefill + decode over the unified model API.

The engine sits on top of the serve subsystem's cache mechanisms:

  * ``cache.CachePool``   — one padded cache buffer, per-slot alloc/free
    (the ``contiguous`` backend: every request owns a full max_len row).
  * ``paged.BlockManager`` — one block-pool buffer, per-request block tables
    (the ``paged`` backend: a request owns ceil(len / block_size) blocks),
    optionally with ref-counted content-hashed prefix caching.
  * ``scheduler.ContinuousScheduler`` — admission + per-step join/evict,
    FCFS/SJF queue ordering; paged pools admit by free *blocks*; admitted
    requests pass through the scheduler's prefill queue.

Every mode is the same engine loop. *Static* batching is the degenerate
scheduler configuration (all requests arrive at step 0 into a pool with one
slot per request, so there is exactly one admission round and no mid-flight
join/evict); *continuous* batching bounds the pool and lets the scheduler
join/evict per step. TP/DP-sharded decode is the same loop again with a
``sharded.ServeSharding`` plan installed (see serve/sharded.py).

Prefill (contiguous): attention-family models (dense / vlm / moe) run ONE
full forward pass capturing the per-layer K/V via ``return_cache``;
recurrent families (ssm / hybrid / encdec) scan decode steps. Prefill is
per-request at the exact prompt length — no cross-request padding — so a
request's output never depends on what it was batched with, which is what
makes continuous and static batching produce identical per-request outputs.

Prefill (paged): prompts prefill in ``block_size`` chunks through each
request's block table, and chunks from up to ``prefill_lanes`` joining
requests pack into ONE jitted ``[P, block_size]`` dispatch per chunk-round
(padded lanes masked) — admitting N requests costs O(chunk-rounds)
dispatches instead of O(N x chunks). Lanes never interact: each lane writes
through its own table, pad positions write nothing, and MoE lanes carry
per-lane expert counts and per-lane routing capacity so batched chunked
routing equals each request's solo one-pass routing. With the prefix cache
on, a lane starts at its first non-cached block and skips the compute for
shared prompt blocks entirely.

Decode (the hot path): one jitted *horizon* dispatch runs up to
``decode_horizon`` steps entirely on device — ``lax.scan`` over the
single-step decode with on-device token selection (greedy argmax, or the
per-slot RNG lanes), token feedback, per-row ``pos`` advance, and per-row
budget/EOS stop masks (a finished row freezes: its token and position stop
advancing and its KV writes are masked) — returning only the ``[W, K]``
int32 token block. The scheduler intervenes at horizon boundaries instead
of every token, so host<->device traffic per generated token drops from a
full ``[B, vocab]`` logits fetch plus state re-uploads to ``1/K``-th of one
``[W, K]`` int32 fetch.

Between horizons the decode state is device-resident (``_DecodeState``):
last tokens, per-row ``pos``, per-row stop positions, and (paged) the block
tables live on device and receive *delta* scatters only at admission,
block growth, eviction, and preemption — never a per-step re-upload.

Both backends compact the decode batch to the live slots: the width is the
smallest power of two covering the active rows, rounded up to a multiple of
the mesh 'data' axis so the bucket shards evenly (see
``ServeSharding.bucket_shardings``). The paged bucket addresses the cache
through gathered block tables (compaction is free); the contiguous bucket
gathers/scatters the pool rows inside the same jitted horizon — on one
device or SPMD-sharded over the mesh. The saved work is reported as
``decode_rows_saved``.

Token selection: greedy by default (the exactness/verify path). With
``temperature > 0`` each slot samples on its own RNG lane —
``jax.random.fold_in`` on the slot id and the decode step — optionally
top-k-truncated, so lanes never interact across slots; the fold is
identical on- and off-horizon, so ``decode_horizon=1`` degenerates to the
classic one-step loop token for token.
"""
from __future__ import annotations

import contextlib
import functools
import math
import time
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig
from repro.models.api import Model, build_model
from repro.obs import NULL_PROFILER, NULL_TRACER, RunObs
from repro.serve.cache import CachePool
from repro.serve.elastic import ScalePlan, pool_capacity
from repro.serve.paged import BlockManager
from repro.serve.scheduler import ContinuousScheduler, ServeRequest
from repro.serve.tenant import SLOSlack, TenantAllocation, TenantRegistry

#: back-compat alias — the original single-file engine exported ``Request``
Request = ServeRequest

_ATTN_PREFILL_FAMILIES = ("dense", "vlm", "moe")
CACHE_BACKENDS = ("contiguous", "paged")


def _pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def _pow2_floor(n: int) -> int:
    b = 1
    while b * 2 <= n:
        b *= 2
    return b


def _bucket(n: int, cap: int, multiple: int = 1) -> int:
    """Compacted decode width: smallest power of two >= n, rounded up to a
    multiple of the mesh 'data' axis size (so bucketed rows shard evenly),
    capped at the pool width — a bounded number of XLA programs covers
    every live-slot count."""
    b = _pow2(max(n, 1))
    if multiple > 1:
        b = -(-b // multiple) * multiple
    return min(b, cap)


@dataclass
class ServeStats:
    n_requests: int
    new_tokens: int
    steps: int
    wall_s: float
    tokens_per_s: float
    slot_utilization: float           # mean active/n_slots over decode steps
    mean_latency_steps: float
    p95_latency_steps: float
    mean_latency_s: float
    max_active: int = 0               # peak concurrently-decoding requests
    # -- completion accounting -------------------------------------------------
    unfinished: int = 0               # NON-dropped requests that never
                                      # finished (or finished without
                                      # wall-clock stamps — e.g. evicted at
                                      # driver shutdown); they count as SLO
                                      # misses so silent losses can never
                                      # inflate attainment
    slo_attainment: float = 1.0       # fraction of NON-dropped requests
                                      # meeting their tenant's SLO (1.0 when
                                      # no tenant carries one). Dropped
                                      # requests are excluded from the
                                      # denominator — and surfaced in
                                      # ``dropped`` — so injected kills can
                                      # neither inflate nor deflate it.
    #: per-tenant latency + SLO summary (tenant id -> dict with
    #: p50/p99_latency_steps, p50/p99_latency_s, slo_attainment,
    #: n_requests, unfinished, preemptions) — None without tenant tags
    tenants: Optional[dict] = field(default=None)
    decode_rows_saved: float = 0.0    # live-slot compaction: fraction of
                                      # pool rows never decoded
    preemptions: int = 0              # paged: requests bounced on pool
                                      # pressure (regenerated exactly)
    block_report: Optional[dict] = field(default=None)
    # -- phase split + dispatch accounting ------------------------------------
    prefill_s: float = 0.0            # wall seconds inside prefill dispatch
    decode_s: float = 0.0             # wall seconds inside decode dispatch
    prefill_dispatches: int = 0       # jitted prefill calls (paged: one per
                                      # chunk-round across ALL joining lanes)
    decode_dispatches: int = 0        # jitted decode horizons (each covers
                                      # up to decode_horizon steps)
    # -- decode horizon -------------------------------------------------------
    decode_horizon: int = 1           # configured K: decode steps per
                                      # jitted dispatch
    host_syncs: int = 0               # device->host sync points (one [W, K]
                                      # int32 fetch per horizon + one id
                                      # fetch per prefill pick round)
    # -- prefix cache ---------------------------------------------------------
    prefix_blocks_total: int = 0      # prompt blocks allocated (paged)
    prefix_blocks_hit: int = 0        # of those, served from the cache
    prefix_hit_rate: float = 0.0
    # -- boundary-sampled series (obs.MetricsRegistry; live with tracing off) --
    mean_queue_depth: float = 0.0     # waiting requests at horizon boundaries
    max_queue_depth: int = 0
    mean_occupancy: float = 0.0       # pool occupancy at horizon boundaries
    max_occupancy: float = 0.0        # (paged: used blocks; contig: slots)
    # -- dispatch profiling (obs.prof; 0.0 with profiling off) -----------------
    decode_util: float = 0.0          # mean measured-vs-roofline utilization
                                      # over execute decode dispatches
    # -- fault injection (serve/chaos.py; all 0 without an injector) -----------
    faults_injected: int = 0          # faults applied at horizon boundaries
    recoveries: int = 0               # recovery actions taken (regenerate /
                                      # retry / restore / rescale / drop)
    dropped: int = 0                  # requests given up on by a recovery
                                      # path (bounded retries exhausted, or
                                      # the shrunken pool can never hold
                                      # them) — counted SEPARATELY from
                                      # unfinished
    # -- elastic reshapes (serve/elastic.py; all 0 without reshapes) -----------
    scale_ups: int = 0                # applied scale_up reshapes
    scale_downs: int = 0              # applied scale_down reshapes
    migrated_blocks: int = 0          # live blocks migrated across a
                                      # physical pool growth (grow_physical)
    replans: int = 0                  # allocator re-plans at reshape
                                      # boundaries (measured-rate refresh)


@dataclass
class _PrefillLane:
    """One live lane of the batched paged prefill: a joining request, its
    chunk cursor (starting past any prefix-cache hits), and its carried
    cross-chunk state (MoE expert counts; None for dense/vlm)."""
    req: ServeRequest
    prompt: np.ndarray
    ptr: int
    cap_row: int
    state: Optional[np.ndarray]


class _DecodeState:
    """Device-resident decode-loop state.

    The last token, per-row ``pos``, and per-row freeze position ``stop``
    (plus the paged block tables) stay on device between horizon
    dispatches; the host scatters *deltas* at admission, growth, eviction,
    and preemption only. ``stop`` is the position at which a row freezes
    (``prompt_len + max_new - 1`` — the budget's last write position + 1);
    a row is live while ``pos < stop``, so zeroed rows (idle slots, frozen
    evictees) are inert horizon padding. Sharded engines keep these arrays
    replicated — a few int32 per slot, delta-updated from the host — and
    the horizon gathers each bucket with the width's NamedSharding.
    """

    def __init__(self, n_slots: int, max_blocks: Optional[int] = None,
                 sharding=None):
        rep = sharding.replicated() if sharding is not None else None
        put = (lambda x: jax.device_put(x, rep)) if rep is not None \
            else (lambda x: x)
        self.tok = put(jnp.zeros((n_slots, 1), jnp.int32))
        self.pos = put(jnp.zeros((n_slots,), jnp.int32))
        self.stop = put(jnp.zeros((n_slots,), jnp.int32))
        self.tables = (put(jnp.full((n_slots, max_blocks), -1, jnp.int32))
                       if max_blocks else None)

    def set_rows(self, slots, toks, pos, stop) -> None:
        """Install freshly-prefilled rows (paged table rows arrive via
        ``set_tables`` from the pool's dirty-slot drain — admission marks
        its slots dirty, so the rows upload exactly once)."""
        idx = jnp.asarray(np.asarray(slots, np.int32))
        self.tok = self.tok.at[idx].set(
            jnp.asarray(np.asarray(toks, np.int32)[:, None]))
        self.pos = self.pos.at[idx].set(
            jnp.asarray(np.asarray(pos, np.int32)))
        self.stop = self.stop.at[idx].set(
            jnp.asarray(np.asarray(stop, np.int32)))

    def set_tables(self, slots, rows) -> None:
        idx = jnp.asarray(np.asarray(slots, np.int32))
        self.tables = self.tables.at[idx].set(
            jnp.asarray(np.asarray(rows, np.int32)))

    def freeze(self, slots) -> None:
        """stop=0 for vacated slots: frozen rows never advance, never write
        KV, and (paged) never scatter through a stale block table."""
        slots = sorted(slots)
        if slots:
            idx = jnp.asarray(np.asarray(slots, np.int32))
            self.stop = self.stop.at[idx].set(0)


def _scan_horizon(step_fn, pick, eos, cache, t, p, s, idx, step0, h):
    """The shared horizon scan: up to ``h`` decode steps on device over a
    gathered bucket — one ``step_fn(cache, tokens, pos, active)`` per step
    (contiguous or paged, the only difference between the backends' horizon
    programs), on-device selection, token feedback, per-row pos advance,
    and the budget/EOS stop masks. A row is live while ``p < s``; frozen
    rows keep (token, pos) and emit the -1 sentinel. Returns
    (cache, t, p, s, token block [W, h])."""
    def body(carry, k):
        cache, t, p, s = carry
        active = p < s
        logits, cache = step_fn(cache, t, p, active)
        nxt = pick(logits[:, -1], idx, step0 + k)
        emitted = jnp.where(active, nxt, -1)
        t = jnp.where(active[:, None], nxt[:, None], t)
        p = p + active.astype(jnp.int32)
        if eos is not None:
            s = jnp.where(active & (nxt == eos), p, s)
        return (cache, t, p, s), emitted

    (cache, t, p, s), toks = jax.lax.scan(
        body, (cache, t, p, s), jnp.arange(h, dtype=jnp.int32))
    return cache, t, p, s, toks.T


class ServeEngine:
    """Serving engine for any architecture family.

    ``n_slots=None`` (default) sizes the pool to the request set at each
    ``run``/``generate`` call — classic static batching. A fixed ``n_slots``
    bounds the pool and turns on continuous batching: the scheduler queues
    the overflow and joins/evicts requests per decode step.

    ``cache="paged"`` (attention families) swaps the per-slot max_len rows
    for the block-pool cache: admission becomes block-granular (a request
    costs blocks proportional to its length), prefill is chunked and
    lane-batched across joining requests (``prefill_lanes``), shared prompt
    prefixes hit the content-addressed block cache (``prefix_cache``), and
    decode compacts to the live slots. Outputs stay token-identical to
    contiguous.

    ``decode_horizon=K`` runs up to K decode steps per jitted dispatch, all
    on device (``decode_horizon=1`` is the classic per-token loop; any K is
    token-identical under greedy decoding). ``eos_token`` stops a row early
    when it emits that token (the EOS half of the per-row stop mask; budget
    stops always apply).

    ``tenants`` + ``allocation`` turn on Synergy-style multi-tenant serving
    (serve/tenant.py): requests carry tenant tags, ``policy="slo"`` orders
    admission by SLO slack, preemption victims are picked by LARGEST slack,
    and a ``TenantAllocation`` adds per-tenant cache-unit budgets at
    admission, per-tenant watermark headroom, prefill-lane shares, and a
    per-boundary horizon cap from the allocator's K knee. Every mechanism
    is ordering/allocation only — per-request outputs stay token-identical
    to the single-tenant engine (the exactness invariant ``--verify``
    checks end to end).

    ``tracer`` (an ``obs.Tracer``) turns on structured event tracing:
    admissions, evictions, preemptions (with cause), prefill rounds,
    decode-horizon dispatches, and block-pool traffic land in the ring
    buffer (see ``obs.EVENT_SCHEMA``). Tracing never touches computation —
    outputs are identical with it on or off — and with it off every hook
    is a single falsy check. A per-run ``obs.MetricsRegistry`` is always
    live regardless: counters/gauges sampled every ``metrics_every``
    horizon boundaries feed ``ServeStats`` and its queue-depth/occupancy
    summaries.

    ``profiler`` (an ``obs.DispatchProfiler``) turns on dispatch-level
    profiling: every jitted hot path — per-request contiguous prefill,
    lane-batched paged prefill rounds, K-step decode horizons (the
    compaction gather/scatter runs inside the horizon program, tagged by
    its ``full`` flag) — records wall time with compile-vs-execute
    attribution, an analytic roofline utilization ratio, and per-tenant
    cost shares. Read-only like tracing (outputs identical on or off; off
    costs one falsy check per site); held per-ENGINE, not per-run, so the
    seen-signature set spans warm-up runs.
    """

    def __init__(self, cfg: ArchConfig, params=None, max_len: int = 256,
                 rng=None, n_slots: Optional[int] = None,
                 policy: str = "fcfs", sharding=None,
                 cache: str = "contiguous", block_size: int = 16,
                 n_blocks: Optional[int] = None, watermark: float = 0.05,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, prefill_lanes: int = 4,
                 prefix_cache: bool = True, decode_horizon: int = 8,
                 eos_token: Optional[int] = None,
                 tenants: Optional[TenantRegistry] = None,
                 allocation: Optional[TenantAllocation] = None,
                 tracer=None, metrics_every: int = 1, profiler=None,
                 injector=None, max_admit_retries: int = 4,
                 elastic=None, profile_store=None):
        if cache not in CACHE_BACKENDS:
            raise ValueError(f"unknown cache backend {cache!r}; "
                             f"known: {CACHE_BACKENDS}")
        if cache == "paged":
            if cfg.family not in _ATTN_PREFILL_FAMILIES:
                raise ValueError(
                    f"cache='paged' needs an attention family "
                    f"(got {cfg.family!r}: recurrent state is O(1))")
            cfg = cfg.replace(decode_attention="paged")
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.max_len = max_len
        self.n_slots = n_slots
        self.policy = policy
        self.sharding = sharding
        self.cache_kind = cache
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.watermark = watermark
        self.prefill_lanes = max(int(prefill_lanes), 1)
        self.prefix_cache = bool(prefix_cache)
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.decode_horizon = max(int(decode_horizon), 1)
        self.eos_token = None if eos_token is None else int(eos_token)
        self.tenants = tenants
        self.allocation = allocation
        #: event tracer (obs.Tracer) — defaults to the falsy NullTracer, so
        #: every hook below is one truthiness check when tracing is off.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        #: dispatch profiler (obs.DispatchProfiler) — same falsy-default
        #: contract; engine-lifetime (not per-run) so first-call-per-
        #: signature compile attribution survives warm-up runs.
        self.profiler = profiler if profiler is not None else NULL_PROFILER
        #: sample the metrics gauges into time series every N decode
        #: boundaries (0 disables the series; the gauges still update, so
        #: the stats' queue/occupancy summaries survive via the fallback).
        self.metrics_every = max(int(metrics_every), 0)
        #: fault injector (chaos.FaultInjector) — None in production runs.
        #: With one installed the engine polls it at every horizon
        #: boundary, applies due faults, audits block conservation after
        #: each, and swaps its crash-on-exhaustion paths for graceful
        #: degradation (bounded retry-with-backoff, then drop).
        self.injector = injector
        self.max_admit_retries = max(int(max_admit_retries), 1)
        #: elastic controller (elastic.ElasticController) — None disables
        #: proactive reshapes. Polled at every horizon boundary after fault
        #: application; an emitted ScalePlan is applied in place (pool
        #: shrink/expand + mesh re-bucket + allocator re-plan) without
        #: dropping in-flight requests.
        self.elastic = elastic
        #: measured-rate store (obs.prof.ProfileStore) — when installed
        #: alongside a profiler, every reshape re-plan folds this run's
        #: dispatch profile in and re-fits per-token decode rates, so the
        #: allocator's knee model tracks measurement instead of analytic
        #: constants (ROADMAP item 1's first slice).
        self.profile_store = profile_store
        #: the allocation as constructed — reshapes re-plan in place, so
        #: ``run`` restores this before every run to keep warm runs
        #: identical.
        self._allocation0 = allocation
        self._dmult_full = (sharding.axis_size("data")
                            if sharding is not None else 1)
        self._dmult = self._dmult_full
        #: the most recent run's cache pool (set by ``run``): the audit
        #: surface for chaos tests and replay harnesses.
        self.pool = None
        if policy == "slo" and tenants is None:
            raise ValueError("policy='slo' needs a TenantRegistry "
                             "(tenants=...) to compute slack")
        if allocation is not None and tenants is None:
            raise ValueError("a TenantAllocation needs its TenantRegistry "
                             "(tenants=...) installed too")
        self._sample_key = jax.random.key(sample_seed)
        rng = rng if rng is not None else jax.random.key(0)
        with self._rules():
            self.params = (params if params is not None
                           else self.model.init(rng))
        if sharding is not None:
            self.params = jax.device_put(self.params, sharding.param_sharding)
        self._pick_device = self._pick_fn()
        self._pick = jax.jit(self._pick_device)
        if cache == "paged":
            self._prefill = self._paged_prefill_fn()
            self._horizon = self._paged_horizon_fn()
        else:
            self._prefill = jax.jit(self._prefill_fn())
            self._horizon = self._contiguous_horizon_fn()

    def _rules(self):
        """Logical-axis rules context (no-op off-mesh / unsharded)."""
        return (self.sharding.rules() if self.sharding is not None
                else contextlib.nullcontext())

    # -- prefill ---------------------------------------------------------------
    def _prefill_fn(self):
        """(params, tokens[B, S]) -> (last logits [B, 1, V], cache pytree)."""
        cfg, model, max_len = self.cfg, self.model, self.max_len

        if cfg.family in _ATTN_PREFILL_FAMILIES:
            def prefill(params, tokens):
                """One-pass attention prefill via the ``return_cache`` hook."""
                logits, (k, v) = model.module.forward(cfg, params, tokens,
                                                      return_cache=True)
                pad = max_len - tokens.shape[1]
                widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                return logits[:, -1:], {"k": jnp.pad(k, widths),
                                        "v": jnp.pad(v, widths)}
            return prefill

        def prefill(params, tokens):
            """Recurrent prefill: scan decode steps (O(1) state per step)."""
            b, s = tokens.shape
            cache = model.init_cache(b, max_len)
            logits0 = jnp.zeros((b, 1, cfg.vocab_size), jnp.dtype(cfg.dtype))

            def body(carry, t):
                cache, _ = carry
                logits, cache = model.decode_step(
                    params, cache, tokens[:, t][:, None], t)
                return (cache, logits), None

            (cache, logits), _ = jax.lax.scan(body, (cache, logits0),
                                              jnp.arange(s))
            return logits, cache
        return prefill

    def _paged_prefill_fn(self):
        """Jitted lane-batched chunk prefill; ``cap`` is static (it sizes
        the MoE dispatch buffers — per-lane effective capacity is the traced
        ``cap_rows``, so one program covers every prompt length)."""
        mod, cfg = self.model.module, self.cfg

        @functools.partial(jax.jit, static_argnames=("cap",))
        def chunk_fn(params, buffers, tokens, starts, n_valid, tables, state,
                     cap_rows, cap):
            return mod.paged_prefill_chunk(cfg, params, buffers, tokens,
                                           starts, tables, state, cap,
                                           n_valid=n_valid,
                                           cap_rows=cap_rows)
        return chunk_fn

    # -- token selection (greedy / per-slot RNG lanes) -------------------------
    def _pick_fn(self):
        """On-device token selection: logits [N, V] -> token ids [N] int32.

        Greedy argmax unless ``temperature > 0``; sampling folds (slot id,
        decode step) into per-slot RNG lanes. Traced both inside the decode
        horizon's scan body and as the stand-alone jitted ``self._pick`` the
        prefill sites call — only the [N] int32 ids ever cross to the host,
        never the [N, vocab] logits."""
        temp, tk, base = self.temperature, self.top_k, self._sample_key

        def pick(logits, slots, step):
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            key = jax.random.fold_in(base, step)
            keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(slots)
            scaled = logits.astype(jnp.float32) / temp
            if tk:
                kth = jax.lax.top_k(scaled, tk)[0][..., -1:]
                scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
            return jax.vmap(jax.random.categorical)(keys,
                                                    scaled).astype(jnp.int32)
        return pick

    def _select_tokens(self, logits, slots, step, c=None) -> np.ndarray:
        """logits [N, V] -> next tokens [N] (host). Selection runs on device
        (jitted ``_pick``) and only the int32 ids transfer. Prefill call
        sites pass ``~step`` (the complement lane) so a slot's
        prefill-sampled token and its first decode token — which happen at
        the same scheduler step — never draw on the same key."""
        ids = self._pick(logits, jnp.asarray(np.asarray(slots, np.int32)),
                         jnp.int32(step))
        if c is not None:
            c.inc("host_syncs")
        return np.asarray(ids, np.int32)

    # -- decode horizons -------------------------------------------------------
    def _contiguous_horizon_fn(self):
        """Jitted multi-step decode horizon over the pooled cache: gather
        the bucket's rows (cache + state) once, ``lax.scan`` up to ``h``
        decode steps with on-device selection / token feedback / stop
        masks, scatter the rows back. Rows decode independently, so the
        gathered rows' outputs equal a full-pool decode's — the
        gather-decode-scatter compaction, now inside the horizon and also
        SPMD-sharded when a plan is installed."""
        model, max_len = self.model, self.max_len
        from repro.serve.cache import _batch_axis
        probe_a = jax.eval_shape(lambda: model.init_cache(3, max_len))
        probe_b = jax.eval_shape(lambda: model.init_cache(5, max_len))
        axes = jax.tree_util.tree_map(_batch_axis, probe_a, probe_b)
        pick = self._pick_device
        masked = self.cfg.family in _ATTN_PREFILL_FAMILIES
        eos = self.eos_token
        plan = self.sharding

        def horizon(params, buffers, tok, pos, stop, idx, step0, h, full):
            if full:
                # identity bucket: every slot decodes (idle rows are frozen
                # and inert), so skip the gather/scatter copies of the pool
                # the old full-width decode path never paid.
                sub, t, p, s = buffers, tok, pos, stop
            else:
                sub = jax.tree_util.tree_map(
                    lambda b, ax: jnp.take(b, idx, axis=ax), buffers, axes)
                t, p, s = tok[idx], pos[idx], stop[idx]
            if plan is not None:
                bsh = plan.bucket_shardings(idx.shape[0])
                if plan.cache_pspec is not None:
                    sub = jax.tree_util.tree_map(
                        lambda x, sp: jax.lax.with_sharding_constraint(
                            x, NamedSharding(plan.mesh, sp)),
                        sub, plan.cache_pspec)
                t = jax.lax.with_sharding_constraint(t, bsh["tokens"])
                p = jax.lax.with_sharding_constraint(p, bsh["pos"])
                s = jax.lax.with_sharding_constraint(s, bsh["pos"])

            def step_fn(sub, t, p, active):
                if masked:        # frozen rows stop writing KV
                    return model.decode_step(params, sub, t, p,
                                             write_valid=active)
                # recurrent state has no positional write to mask: frozen
                # rows recompute garbage state, discarded at slot reuse.
                return model.decode_step(params, sub, t, p)

            sub, t, p, s, blk = _scan_horizon(step_fn, pick, eos, sub,
                                              t, p, s, idx, step0, h)
            if full:
                return sub, t, p, s, blk
            buffers = jax.tree_util.tree_map(
                lambda b, nb, ax: b.at[(slice(None),) * ax + (idx,)].set(nb),
                buffers, sub, axes)
            tok = tok.at[idx].set(t)
            pos = pos.at[idx].set(p)
            stop = stop.at[idx].set(s)
            return buffers, tok, pos, stop, blk

        return self._jit_horizon(horizon)

    def _paged_horizon_fn(self):
        """Jitted multi-step decode horizon over the block pool: gather the
        bucket's tokens/pos/stop/tables (compaction through block tables is
        free), ``lax.scan`` up to ``h`` steps, scatter the state back.
        Frozen rows mask their KV writes, so a vacated slot's stale table
        can never scatter into a recycled block."""
        model = self.model
        pick = self._pick_device
        eos = self.eos_token
        plan = self.sharding

        def horizon(params, buffers, tok, pos, stop, tables, idx, step0, h,
                    full):
            if full:
                t, p, s, tb = tok, pos, stop, tables
            else:
                t, p, s, tb = tok[idx], pos[idx], stop[idx], tables[idx]
            if plan is not None:
                bsh = plan.bucket_shardings(idx.shape[0])
                t = jax.lax.with_sharding_constraint(t, bsh["tokens"])
                p = jax.lax.with_sharding_constraint(p, bsh["pos"])
                s = jax.lax.with_sharding_constraint(s, bsh["pos"])
                tb = jax.lax.with_sharding_constraint(tb, bsh["tables"])

            def step_fn(buffers, t, p, active):
                return model.paged_decode_step(params, buffers, t, p, tb,
                                               write_valid=active)

            buffers, t, p, s, blk = _scan_horizon(step_fn, pick, eos,
                                                  buffers, t, p, s, idx,
                                                  step0, h)
            if full:
                return buffers, t, p, s, blk
            tok = tok.at[idx].set(t)
            pos = pos.at[idx].set(p)
            stop = stop.at[idx].set(s)
            return buffers, tok, pos, stop, blk

        return self._jit_horizon(horizon)

    def _jit_horizon(self, horizon):
        """jit with ``h`` (scan length) and ``full`` (identity bucket —
        no gather/scatter) static; sharded plans pin the cache to its
        NamedSharding and the state arrays to replicated so input
        shardings stay stable across calls."""
        plan = self.sharding
        if plan is not None:
            rep = plan.replicated()
            return jax.jit(horizon, static_argnames=("h", "full"),
                           out_shardings=(plan.cache_sharding,
                                          rep, rep, rep, rep))
        return jax.jit(horizon, static_argnames=("h", "full"))

    # -- the engine loop ---------------------------------------------------------
    def run(self, requests: List[ServeRequest]
            ) -> Tuple[List[ServeRequest], ServeStats]:
        """Serve ``requests`` to completion; returns (requests, stats)."""
        reqs = list(requests)
        n_slots = self.n_slots if self.n_slots else max(len(reqs), 1)
        if self.injector is not None:
            # re-arm per run: warm-up double-runs and determinism checks
            # must replay identical chaos (same schedule, same RNG stream)
            self.injector.bind(vocab_size=self.cfg.vocab_size,
                               max_len=self.max_len, n_slots=n_slots)
            self.injector.reset()
        if self.elastic is not None:
            self.elastic.reset()
        # reshapes re-plan the allocation in place mid-run: restore the
        # constructed plan so warm-up double-runs replay identically.
        self.allocation = self._allocation0
        #: live mesh bucketing multiple — a device_fail reshape collapses
        #: it to 1 (non-divisible buckets fall back to replicated
        #: shardings: degraded but exact), a device_join restores it.
        self._dmult_full = (self.sharding.axis_size("data")
                            if self.sharding is not None else 1)
        self._dmult = self._dmult_full
        c = RunObs(self.tracer)
        tr = c.tracer
        if tr:
            tr.step = 0.0
            tr.emit("run_start", backend=self.cache_kind, n_slots=n_slots,
                    horizon=self.decode_horizon, n_requests=len(reqs))
        t0 = time.perf_counter()
        with self._rules():
            if self.cache_kind == "paged":
                self._run_paged(reqs, n_slots, c)
            else:
                self._run_contiguous(reqs, n_slots, c)

        wall = time.perf_counter() - t0
        if tr:
            tr.emit("run_end", steps=c.value("steps"), wall_s=wall)
        return reqs, self._stats(reqs, c, n_slots, wall)

    # -- stats aggregation -----------------------------------------------------
    def _finished(self, r: ServeRequest) -> bool:
        """A request counts as finished only with BOTH clocks stamped:
        ``latency_s is None`` (evicted mid-run at driver shutdown, or
        never admitted) makes it ``unfinished`` — explicitly counted, and
        an SLO miss, so drops can never inflate attainment."""
        return (r.done and r.latency_steps is not None
                and r.latency_s is not None)

    def _meets_slo(self, r: ServeRequest) -> bool:
        """Whether ``r`` finished inside its tenant's SLO (both clocks
        when both targets are set; unfinished is always a miss; a tenant
        without targets only asks for completion)."""
        if not self._finished(r):
            return False
        t = self.tenants.get(r.tenant) if self.tenants is not None else None
        if t is None:
            return True
        if t.slo_steps is not None and r.latency_steps > t.slo_steps:
            return False
        if t.slo_s is not None and r.latency_s > t.slo_s:
            return False
        return True

    def _tenant_stats(self, reqs) -> Optional[dict]:
        """Per-tenant p50/p99 latency (steps + wall) and SLO attainment —
        None when neither a registry nor a non-default tag is present."""
        tids = sorted({r.tenant for r in reqs})
        if self.tenants is None and tids in ([], ["default"]):
            return None
        out = {}
        for tid in tids:
            all_rs = [r for r in reqs if r.tenant == tid]
            rs = [r for r in all_rs if not r.dropped]   # scored set
            steps = [r.latency_steps for r in rs if self._finished(r)]
            walls = [r.latency_s for r in rs if self._finished(r)]
            t = self.tenants.get(tid) if self.tenants is not None else None
            met = sum(1 for r in rs if self._meets_slo(r))
            out[tid] = {
                "n_requests": len(all_rs),
                "unfinished": sum(1 for r in rs if not self._finished(r)),
                "dropped": len(all_rs) - len(rs),
                "preemptions": sum(r.n_preempted for r in rs),
                "p50_latency_steps": (float(np.percentile(steps, 50))
                                      if steps else 0.0),
                "p99_latency_steps": (float(np.percentile(steps, 99))
                                      if steps else 0.0),
                "p50_latency_s": (float(np.percentile(walls, 50))
                                  if walls else 0.0),
                "p99_latency_s": (float(np.percentile(walls, 99))
                                  if walls else 0.0),
                "slo_steps": t.slo_steps if t is not None else None,
                "slo_s": t.slo_s if t is not None else None,
                "slo_attainment": met / len(rs) if rs else 1.0,
            }
        return out

    def _stats(self, reqs, c: RunObs, n_slots, wall) -> ServeStats:
        """Fold the run's metrics registry (plus the per-request latency
        stamps, which stay authoritative) into a ``ServeStats``."""
        m = c.metrics
        new_tokens = sum(len(r.output) for r in reqs)
        lat_steps = [r.latency_steps for r in reqs
                     if r.latency_steps is not None]
        lat_wall = [r.latency_s for r in reqs if r.latency_s is not None]
        steps = int(m.value("steps"))
        rows_possible = steps * n_slots
        hit, total = int(m.value("prefix_hits")), int(m.value("prefix_total"))
        # fault-dropped requests leave the scored set entirely: they are
        # counted in ``dropped``, not ``unfinished``, and excluded from
        # slo_attainment's denominator — an injected kill must neither
        # inflate attainment (drop the misses) nor deflate it (score
        # requests the injector made unservable).
        scored = [r for r in reqs if not r.dropped]
        met = sum(1 for r in scored if self._meets_slo(r))
        qd_mean, qd_max = m.series_stats("queue_depth")
        occ_mean, occ_max = m.series_stats("occupancy")
        stats = ServeStats(
            n_requests=len(reqs),
            new_tokens=new_tokens,
            steps=steps,
            wall_s=wall,
            tokens_per_s=new_tokens / wall if wall > 0 else 0.0,
            slot_utilization=m.value("util_acc") / steps if steps else 0.0,
            mean_latency_steps=float(np.mean(lat_steps)) if lat_steps else 0.0,
            p95_latency_steps=(float(np.percentile(lat_steps, 95))
                               if lat_steps else 0.0),
            mean_latency_s=float(np.mean(lat_wall)) if lat_wall else 0.0,
            max_active=int(m.value("max_active")),
            decode_rows_saved=(1.0 - m.value("rows_decoded") / rows_possible
                               if rows_possible else 0.0),
            preemptions=int(m.value("preemptions")),
            block_report=c.block_report,
            prefill_s=m.value("prefill_s"),
            decode_s=m.value("decode_s"),
            prefill_dispatches=int(m.value("prefill_dispatches")),
            decode_dispatches=int(m.value("decode_dispatches")),
            decode_horizon=self.decode_horizon,
            host_syncs=int(m.value("host_syncs")),
            prefix_blocks_total=total,
            prefix_blocks_hit=hit,
            prefix_hit_rate=hit / total if total else 0.0,
            unfinished=sum(1 for r in scored if not self._finished(r)),
            slo_attainment=met / len(scored) if scored else 1.0,
            faults_injected=int(m.value("faults_injected")),
            recoveries=int(m.value("recoveries")),
            dropped=len(reqs) - len(scored),
            tenants=self._tenant_stats(reqs),
            mean_queue_depth=qd_mean,
            max_queue_depth=int(qd_max),
            mean_occupancy=occ_mean,
            max_occupancy=occ_max,
            decode_util=m.series_stats("util[decode]")[0],
            scale_ups=int(m.value("scale_ups")),
            scale_downs=int(m.value("scale_downs")),
            migrated_blocks=int(m.value("migrated_blocks")),
            replans=int(m.value("replans")),
        )
        return stats

    def _sample_boundary(self, sched, pool, c: RunObs, n_slots: int) -> None:
        """Update the live gauges after a decode boundary and, every
        ``metrics_every`` boundaries, snapshot them (and every counter)
        into the registry's time series — the substrate for the stats'
        queue/occupancy summaries and ``trace_report``'s timelines. Always
        on: a handful of float stores per horizon (not per token)."""
        m = c.metrics
        c.boundaries += 1
        m.set("queue_depth", len(sched.waiting))
        m.set("active", len(sched.active))
        if self.cache_kind == "paged":
            occ = (1.0 - pool.free_blocks / pool.n_blocks
                   if pool.n_blocks else 0.0)
        else:
            # live capacity, not physical slots: a reshape-revoked slot no
            # longer counts as headroom the elastic controller could fill.
            cap = getattr(pool, "capacity", n_slots)
            occ = len(sched.active) / cap if cap else 0.0
        m.set("occupancy", occ)
        every = self.metrics_every
        if every and c.boundaries % every == 0:
            if self.tenants is not None:
                live = list(sched.waiting) + list(sched.active.values())
                for t in self.tenants:
                    slk = min((self._slack(r, sched.step) for r in live
                               if r.tenant == t.tenant_id),
                              default=math.inf)
                    if math.isfinite(slk):
                        m.set(f"slack[{t.tenant_id}]", slk)
            m.sample(sched.step)

    # -- horizon scheduling helpers (host side) --------------------------------
    def _make_sched(self, pool) -> ContinuousScheduler:
        """The scheduler for one run: SLO-slack ordering when asked for
        (``policy='slo'`` resolves against the tenant registry) and the
        per-tenant budget check when an allocation is installed."""
        policy = (SLOSlack(self.tenants) if self.policy == "slo"
                  else self.policy)
        return ContinuousScheduler(pool, policy, allocation=self.allocation,
                                   tracer=self.tracer)

    def _slack(self, req, step) -> float:
        """SLO slack in decode steps (+inf without a registry or SLO)."""
        if self.tenants is None:
            return math.inf
        return self.tenants.slack(req, step)

    def _evict(self, sched, state: _DecodeState, c: Optional[RunObs] = None):
        """Evict finished requests and freeze their device rows, so a
        vacated slot gathered as horizon padding can never decode as live
        (or, paged, write KV through a stale block table)."""
        done_slots = [s for s, r in sched.active.items() if r.done]
        out = sched.evict_finished()
        state.freeze(done_slots)
        if c is not None and out:
            for slot, r in zip(done_slots, out):
                c.metrics.observe("latency_steps", r.latency_steps)
                if c.tracer:
                    t = (self.tenants.get(r.tenant)
                         if self.tenants is not None else None)
                    c.tracer.emit(
                        "evict", req=r.job_id, tenant=r.tenant, slot=slot,
                        latency_steps=r.latency_steps,
                        finished_early=r.finished_early,
                        slo_steps=t.slo_steps if t is not None else None,
                        met=self._meets_slo(r))
        return out

    # -- fault injection + recovery (serve/chaos.py) ---------------------------
    def _fault_hold(self, sched):
        """The admission-hold hook (``tenant_slowdown`` / ``defer_storm``
        windows): None — the common case — costs the scheduler nothing."""
        inj = self.injector
        if inj is None or not inj.has_holds(sched.step):
            return None
        return lambda r: inj.hold_cause(r, sched.step)

    def _drop(self, sched, req, c: RunObs, cause: str) -> None:
        """Give up on a waiting request (a recovery path exhausted): it
        leaves the queue with ``dropped`` set so stats score it separately
        from unfinished work."""
        if req in sched.waiting:
            sched.waiting.remove(req)
        req.dropped = True
        req.drop_cause = cause
        c.inc("recoveries")
        if c.tracer:
            c.tracer.emit("recover", kind=cause, action="drop",
                          req=req.job_id, detail=req.n_retries)

    def _pending_units(self, pool, step) -> int:
        """Capacity units scheduled to ARRIVE after ``step``: pending
        ``pool_restore`` / ``device_join`` faults plus the elastic
        controller's unexercised scale-up headroom — the difference
        between "this pool will never hold it" (drop) and "capacity is
        coming back" (hold under bounded retry)."""
        pend = 0
        if self.injector is not None and step is not None:
            pend += self.injector.pending_capacity(step)
        if self.elastic is not None:
            pend += self.elastic.pending_units(pool)
        return pend

    def _can_ever_admit(self, pool, req, step=None) -> bool:
        """Whether the pool capacity — current PLUS capacity scheduled to
        return (pending restores/joins, proactive scale-up headroom) —
        could ever admit ``req``: the difference between "wait for blocks"
        (retry/hold) and "will never hold it" (drop). Mirrors
        ``validate_request``'s arithmetic against the live ``n_blocks``.
        Conservative on prefix hits: a request droppable by this rule
        might have admitted via cached blocks, but bounded retries have
        already been burned by then."""
        if not hasattr(pool, "blocks_for"):
            return True                      # contiguous slots never vanish
        need = len(req.prompt) + req.max_new_tokens
        if need > pool.max_len:
            return False                     # no capacity fixes the span
        cap = pool.n_blocks + self._pending_units(pool, step)
        return (pool.blocks_for(need) <= cap
                and pool.blocks_for(len(req.prompt)) + pool.watermark_blocks
                <= cap)

    def _chaos_admission(self, sched, pool, c: RunObs) -> None:
        """Bounded retry-with-backoff for waiting requests a ``pool_shrink``
        left unservable: each due retry re-checks capacity (a restore
        resets the clock), backs off exponentially, and after
        ``max_admit_retries`` the request drops instead of wedging the
        queue forever."""
        for r in list(sched.waiting):
            if r.arrival_time > sched.step:
                continue
            if self._can_ever_admit(pool, r, step=sched.step):
                r.n_retries = 0              # capacity is back (or coming
                continue                     # back): clean slate
            if sched.step < r.next_retry:
                continue
            r.n_retries += 1
            if r.n_retries > self.max_admit_retries:
                self._drop(sched, r, c, cause="pool_shrink")
                continue
            r.next_retry = sched.step + float(2 ** r.n_retries)
            c.inc("recoveries")
            if c.tracer:
                c.tracer.emit("recover", kind="pool_shrink", action="retry",
                              req=r.job_id, detail=r.n_retries)

    def _next_unblock(self, sched) -> Optional[float]:
        """The earliest future step at which a stalled queue could move
        again: an arrival, a hold release, a pending fault, or a backoff
        retry — where the idle clock jumps to instead of crashing when
        chaos has made every waiting request momentarily inadmissible."""
        cands = [r.arrival_time for r in sched.waiting
                 if r.arrival_time > sched.step]
        cands += [r.next_retry for r in sched.waiting
                  if r.next_retry > sched.step]
        inj = self.injector
        if inj is not None:
            for s in (inj.release_step(sched.step),
                      inj.next_fault_step(sched.step)):
                if s is not None and s > sched.step:
                    cands.append(s)
        return min(cands, default=None)

    def _apply_faults(self, sched, pool, state, c: RunObs, n_slots: int,
                      reqs: List[ServeRequest]) -> None:
        """Apply every due fault at this boundary, then audit block
        conservation (paged) — a fault that corrupts pool accounting must
        fail HERE, at the injection site, not decodes later."""
        for f in self.injector.due(sched.step):
            self._apply_fault(f, sched, pool, state, c, n_slots, reqs)
            self.injector.injected.append((f.kind, float(sched.step)))
            c.inc("faults_injected")
            if isinstance(pool, BlockManager):
                pool.audit()

    def _apply_fault(self, f, sched, pool, state, c: RunObs, n_slots: int,
                     reqs: List[ServeRequest]) -> None:
        tr = c.tracer
        inj = self.injector
        paged = isinstance(pool, BlockManager)
        if f.kind == "pool_shrink":
            took = pool.shrink(f.blocks) if paged else 0
            if tr:
                tr.emit("fault_inject", kind=f.kind, target=None, mag=took)
            if took and f.restore_after is not None:
                inj.defer_restore(f, float(sched.step), took)
            if took and self.allocation is not None:
                pool.tenant_reserves = self.allocation.rescaled_reserves(
                    pool.n_blocks)
                c.inc("recoveries")
                if tr:
                    tr.emit("recover", kind=f.kind, action="reserve_rescale",
                            req=None, detail=sum(
                                pool.tenant_reserves.values()))
        elif f.kind == "pool_restore":
            got = pool.expand(f.blocks) if paged else 0
            if got and self.allocation is not None:
                pool.tenant_reserves = self.allocation.rescaled_reserves(
                    pool.n_blocks)
            c.inc("recoveries")
            if tr:
                tr.emit("recover", kind="pool_shrink", action="restore",
                        req=None, detail=got)
        elif f.kind == "device_fail":
            # a data-parallel device leaves: its share of the pool is
            # revoked AND the mesh bucketing multiple collapses to 1, so
            # subsequent buckets fall back to replicated shardings
            # (degraded but exact). In-flight rows keep their device
            # state — the reshape is reorder-only.
            took = self._apply_scale(sched, pool, state, c, ScalePlan(
                kind="scale_down", units=f.blocks, reason="device_fail",
                step=float(sched.step), dmult=1))
            if tr:
                tr.emit("fault_inject", kind=f.kind, target=None, mag=took)
            if f.restore_after is not None:
                # schedule the join even when 0 blocks were revocable —
                # the mesh multiple must still be restored.
                inj.defer_restore(f, float(sched.step), took)
        elif f.kind == "device_join":
            got = self._apply_scale(sched, pool, state, c, ScalePlan(
                kind="scale_up", units=f.blocks, reason="device_join",
                step=float(sched.step), dmult=self._dmult_full))
            c.inc("recoveries")
            if tr:
                tr.emit("recover", kind="device_fail", action="restore",
                        req=None, detail=got)
        elif f.kind == "slot_kill":
            slot = inj.pick_slot(list(sched.active), f.slot)
            if slot is None:
                if tr:
                    tr.emit("fault_inject", kind=f.kind, target=None, mag=0)
                return
            victim = sched.active[slot]
            if tr:
                tr.emit("fault_inject", kind=f.kind, target=slot, mag=1)
            # the device state is declared lost: preempt-and-regenerate is
            # exactly the recovery — blocks freed, the row frozen, tokens
            # regenerated identically after re-admission (deterministic
            # prefill + greedy decode), so outputs stay token-identical.
            sched.preempt(victim, cause="slot_kill")
            state.freeze([slot])
            c.inc("preemptions")
            c.inc("recoveries")
            if tr:
                tr.emit("recover", kind=f.kind, action="regenerate",
                        req=victim.job_id, detail=victim.n_preempted)
        elif f.kind in ("tenant_slowdown", "defer_storm"):
            tenant = f.tenant if f.kind == "tenant_slowdown" else None
            inj.hold(tenant, float(sched.step) + f.duration)
            if tr:
                tr.emit("fault_inject", kind=f.kind, target=tenant,
                        mag=f.duration)
        elif f.kind == "arrival_burst":
            burst = inj.burst_requests(f)
            if tr:
                tr.emit("fault_inject", kind=f.kind, target=f.tenant,
                        mag=len(burst))
            for r in burst:
                r.job_id = len(reqs)
                r.arrival_time = float(sched.step)
                reqs.append(r)          # stats score the injected load too
                try:
                    sched.submit(r)
                except ValueError:
                    # the CURRENT pool can never fit it — but a scheduled
                    # restore/join may bring that capacity back: hold it
                    # for the bounded-retry path instead of dropping.
                    if self._can_ever_admit(pool, r, step=sched.step):
                        sched.park(r)
                        c.inc("recoveries")
                        if tr:
                            tr.emit("recover", kind=f.kind, action="retry",
                                    req=r.job_id, detail=0)
                    else:
                        self._drop(sched, r, c, cause="burst_unservable")
        elif f.kind == "prefix_flush":
            flushed = pool.flush_prefix() if paged else 0
            if tr:
                tr.emit("fault_inject", kind=f.kind, target=None,
                        mag=flushed)

    # -- elastic reshapes (serve/elastic.py) -----------------------------------
    def _apply_scale(self, sched, pool, state, c: RunObs, plan) -> int:
        """Apply one ``ScalePlan`` at a horizon boundary — the ONLY place
        reshapes happen, so every device-resident row (KV blocks, block
        tables, decode tok/pos/stop) is at a consistent step when capacity
        moves. Scale-down revokes idle capacity (in-flight rows keep their
        state); scale-up returns revoked capacity first and, paged, grows
        the pool PAST its constructed size via ``grow_physical`` — the
        live blocks migrate into the reallocated buffers, timed and traced
        as a ``migrate`` event. A ``dmult`` change re-buckets the mesh
        'data' axis for every subsequent dispatch (widths that stop
        dividing it fall back to replicated shardings — degraded but
        exact). Afterwards tenant reserves re-split against the new
        capacity and the allocator re-plans (``_replan``). Returns the
        capacity units actually moved."""
        tr = c.tracer
        paged = isinstance(pool, BlockManager)
        old_dmult = self._dmult
        if plan.kind == "scale_down":
            moved = pool.shrink(plan.units)
        else:
            moved = pool.expand(plan.units)  # revoked ledger first
            extra = plan.units - moved
            if extra > 0 and paged:
                live = (pool._total_blocks - len(pool._free_blocks)
                        - len(pool._revoked))
                t0 = time.perf_counter()
                sh = (self.sharding.cache_sharding
                      if self.sharding is not None else None)
                added = pool.grow_physical(extra, sharding=sh)
                if added:
                    moved += added
                    c.inc("migrated_blocks", live)
                    if tr:
                        tr.emit("migrate", blocks=live, added=added,
                                dur_s=time.perf_counter() - t0)
        if plan.dmult is not None:
            self._dmult = max(int(plan.dmult), 1)
        if not moved and self._dmult == old_dmult:
            return 0                         # nothing applied: no event
        c.inc("scale_ups" if plan.kind == "scale_up" else "scale_downs")
        if tr:
            tr.emit(plan.kind, units=moved, capacity=pool_capacity(pool),
                    dmult=self._dmult, reason=plan.reason)
        if moved and paged and self.allocation is not None:
            pool.tenant_reserves = self.allocation.rescaled_reserves(
                pool.n_blocks)
        if moved:
            self._replan(sched, pool, c)
        if self.elastic is not None:
            self.elastic.note_scale(sched.step, plan)
        if paged:
            pool.audit()                     # conservation must hold HERE,
                                             # after every migration
        return moved

    def _replan(self, sched, pool, c: RunObs) -> None:
        """Re-run the profile + allocate pipeline against the reshaped
        capacity: tenant demand is re-profiled from the LIVE request mix,
        per-token decode rates come from the measured ``ProfileStore`` fit
        when one is installed (this run's dispatch profile folds in first,
        so the fit reads the freshest rates), and the allocator re-plans
        budgets, K-knees, and lane shares for the new pool — calibration
        tracks measurement across every reshape instead of the one plan
        struck at startup. A tenant-carrying engine that started WITHOUT a
        plan gets its first one here (capacity just changed under it, so
        the slack-only scheduler now wants budgets). Allocation-only:
        outputs stay token-identical."""
        if self.tenants is None:
            return
        from repro.serve.tenant import (plan_allocation, profile_class,
                                        profiles_from_requests)
        max_k = (self.allocation.max_k if self.allocation is not None
                 else self.decode_horizon)
        store = self.profile_store
        if store is not None and self.profiler:
            store.add_run(self.profiler, arch=self.cfg.arch_id,
                          backend=self.cache_kind)
        total = pool_capacity(pool)
        live = list(sched.waiting) + list(sched.active.values())
        units_for = ((lambda r: pool.blocks_for(len(r.prompt)
                                                + r.max_new_tokens))
                     if hasattr(pool, "blocks_for") else None)
        profiles = profiles_from_requests(
            self.tenants, live, total_units=total, units_for=units_for,
            max_k=max_k, store=store, arch=self.cfg.arch_id,
            backend=self.cache_kind)
        for t in self.tenants:
            if t.tenant_id not in profiles:  # drained tenant: keep a
                profiles[t.tenant_id] = profile_class(  # minimal profile
                    t.tenant_id, units_per_req=1, concurrency=1,
                    total_units=total, max_k=max_k,
                    store=store, arch=self.cfg.arch_id,
                    backend=self.cache_kind)
        wm = (pool.watermark_blocks if hasattr(pool, "watermark_blocks")
              else 0)
        self.allocation = plan_allocation(
            self.tenants, profiles, total, total_lanes=self.prefill_lanes,
            max_k=max_k, watermark_units=wm)
        sched.allocation = self.allocation
        if isinstance(pool, BlockManager):
            pool.tenant_reserves = self.allocation.reserves()
        c.inc("replans")
        if c.tracer:
            c.tracer.emit("recover", kind="reshape", action="replan",
                          req=None, detail=int(total))

    def _submit_all(self, sched, pool, reqs) -> None:
        """Submit the run's initial requests. A request the CONSTRUCTED
        pool cannot validate is parked instead of rejected when scheduled
        capacity (a pending ``device_join``/``pool_restore``, or elastic
        scale-up headroom) will cover it — the bounded-retry admission
        path then holds it until the capacity arrives. Without pending
        capacity the submit error propagates exactly as before."""
        for i, r in enumerate(reqs):
            r.job_id = i
            try:
                sched.submit(r)
            except ValueError:
                if not self._can_ever_admit(pool, r, step=float(sched.step)):
                    raise
                sched.park(r)

    def _elastic_poll(self, sched, pool, state, c: RunObs) -> None:
        """Ask the elastic controller for a proactive reshape at this
        boundary (None without a controller, inside its cooldown, or when
        every signal sits between the thresholds)."""
        if self.elastic is None:
            return
        plan = self.elastic.decide(sched.step, pool, c.metrics)
        if plan is not None:
            self._apply_scale(sched, pool, state, c, plan)

    def _could_admit_arrival(self, sched) -> bool:
        """Whether shortening the horizon for the next arrival could pay
        off: the pool must actually be able to admit a waiting request —
        free slots for the contiguous pool, watermark-clearing blocks for
        the paged pool (``can_admit`` is cache-blind, matching the
        admission rule; the cap is a heuristic either way)."""
        pool = sched.pool
        if hasattr(pool, "can_admit"):
            return any(pool.can_admit(len(r.prompt)) for r in sched.waiting)
        return getattr(pool, "n_free", 0) > 0

    def _pick_h(self, sched, act) -> int:
        """Horizon length for this dispatch: at most ``decode_horizon``,
        capped to the longest remaining budget (every scanned step then
        serves at least one live row) and to the next open-loop arrival
        when the pool could admit it — the scheduler only intervenes at
        horizon boundaries.

        Tenant-aware boundaries (serve/tenant.py): the allocator's
        per-tenant horizon knee caps ``h`` (the LARGEST knee among the
        active tenants — a K past every knee buys no throughput), and when
        a QUEUED request's SLO slack is shorter than the horizon, ``h``
        shrinks toward that slack so the boundary — where eviction frees
        capacity and slack-ordered admission runs — lands before the
        deadline pressure instead of after it.

        The result is quantized DOWN to a power of two: ``h`` is a static
        jit argument, so free-running values would compile one K-step
        program per (width, h) pair — quantization bounds the program set
        to log2(K) entries per width."""
        rem = max(sched.active[s].max_new_tokens - len(sched.active[s].output)
                  for s in act)
        h = max(1, min(self.decode_horizon, rem))
        if self.allocation is not None:
            h = min(h, max(1, self.allocation.k_cap_for(
                {sched.active[s].tenant for s in act})))
        nxt = sched.next_arrival()
        if (nxt is not None and nxt > sched.step
                and self._could_admit_arrival(sched)):
            h = max(1, min(h, int(math.ceil(nxt - sched.step))))
        if self.tenants is not None and sched.waiting:
            urgent = min(self._slack(r, sched.step) for r in sched.waiting)
            if math.isfinite(urgent):
                h = max(1, min(h, int(max(1.0, urgent))))
        if self.injector is not None:
            # land the next boundary on the next pending fault, so a fault
            # keyed to step s applies at the first boundary >= s instead
            # of drifting up to a full horizon late.
            nf = self.injector.next_fault_step(sched.step)
            if nf is not None and nf > sched.step:
                h = max(1, min(h, int(math.ceil(nf - sched.step))))
        return _pow2_floor(h)

    def _decode_boundary(self, sched, pool, state, c, n_slots, dmult,
                         h) -> List[int]:
        """One horizon dispatch at a scheduler boundary (both backends):
        bucket the live rows, run the jitted horizon, unpack the [W, h]
        token block, update the counters and the scheduler clock. Returns
        the per-row emitted counts in sorted-active order."""
        act = sorted(sched.active)
        h = _pow2_floor(min(h, max(sched.active[s].max_new_tokens
                                   - len(sched.active[s].output)
                                   for s in act)))
        bc = _bucket(len(act), n_slots, dmult)
        full = bc == n_slots
        if full:
            idx = np.arange(n_slots, dtype=np.int32)
            rows = act                       # block rows are slot-indexed
        else:
            idle = [s for s in range(n_slots) if s not in sched.active]
            idx = np.asarray(act + idle[:bc - len(act)], np.int32)
            rows = list(range(len(act)))     # compacted row order
        args = (self.params, pool.buffers, state.tok, state.pos, state.stop)
        if state.tables is not None:
            args += (state.tables,)
        t0 = time.perf_counter()
        pool.buffers, state.tok, state.pos, state.stop, blk = self._horizon(
            *args, jnp.asarray(idx), jnp.int32(sched.step), h=h, full=full)
        c.inc("decode_dispatches")
        blk = np.asarray(blk)                # the ONE [W, h] int32 fetch
        c.inc("host_syncs")
        dt = time.perf_counter() - t0
        c.inc("decode_s", dt)
        prof = self.profiler
        if prof:
            # KV positions at dispatch start (outputs not yet extended);
            # tenants maps tenant -> live rows for the cost-share split.
            kv = sum(len(sched.active[s].prompt) + len(sched.active[s].output)
                     for s in act)
            prof.record("decode", dt, width=len(idx), k=h, full=full,
                        kv_pos_sum=kv,
                        tenants=Counter(sched.active[s].tenant for s in act),
                        obs=c)
        counts = self._unpack_horizon(sched, act, rows, blk, h, n_slots, c)
        c.inc("rows_decoded", len(idx) * h)
        c.hi("max_active", len(act))
        c.inc("steps", h)
        c.metrics.observe("horizon_k", h)
        if c.tracer:
            c.tracer.emit("decode_horizon", step=sched.step, k=h,
                          width=len(idx), active=len(act), full=full,
                          dur_s=dt)
        sched.step += h
        if c.tracer:
            c.tracer.step = sched.step
        self._sample_boundary(sched, pool, c, n_slots)
        return counts

    def _unpack_horizon(self, sched, act, rows, blk, h, n_slots,
                        c) -> List[int]:
        """Distribute a horizon's [W, h] token block: the row of active
        slot ``act[i]`` is ``rows[i]``; its first min(h, remaining)
        entries are its tokens (the device freezes finished rows and emits
        -1), truncated at the engine's EOS token. Returns the per-row
        emitted counts (in ``act`` order)."""
        counts = []
        step0 = sched.step
        for slot, row in zip(act, rows):
            r = sched.active[slot]
            m = min(h, r.max_new_tokens - len(r.output))
            toks = [int(x) for x in blk[row, :m]]
            if self.eos_token is not None and self.eos_token in toks:
                toks = toks[:toks.index(self.eos_token) + 1]
                r.finished_early = True
            r.output.extend(toks)
            counts.append(len(toks))
            if r.done and r.finished_at is None:
                # exact finishing step (eviction only happens at the
                # boundary): last token emitted at step0 + count - 1.
                r.finished_at = float(step0 + len(toks))
        for k in range(h):
            c.inc("util_acc", sum(1 for m in counts if m > k) / n_slots)
        return counts

    def _run_contiguous(self, reqs, n_slots, c: RunObs):
        self.pool = pool = CachePool(self.model, n_slots, self.max_len)
        if self.sharding is not None:
            pool.buffers = self.sharding.reshard_cache(pool.buffers)
        sched = self._make_sched(pool)
        self._submit_all(sched, pool, reqs)

        state = _DecodeState(n_slots, sharding=self.sharding)
        tr = c.tracer
        prof = self.profiler

        while sched.has_work:
            if self.injector is not None:
                self._apply_faults(sched, pool, state, c, n_slots, reqs)
            self._elastic_poll(sched, pool, state, c)
            self._evict(sched, state, c)
            sched.admit(hold=self._fault_hold(sched))
            if self.injector is not None or self.elastic is not None:
                self._chaos_admission(sched, pool, c)
            admitted = sched.drain_prefill()
            t0 = time.perf_counter()
            for r in admitted:
                rt0 = time.perf_counter() if (tr or prof) else 0.0
                tokens = jnp.asarray(
                    np.asarray(r.prompt, np.int32))[None, :]
                logits, row = self._prefill(self.params, tokens)
                c.inc("prefill_dispatches")
                pool.write(r.slot, row)
                tok = int(self._select_tokens(logits[:, -1], [r.slot],
                                              ~sched.step, c)[0])
                r.output.append(tok)
                if self.eos_token is not None and tok == self.eos_token:
                    r.finished_early = True
                if tr or prof:
                    rdt = time.perf_counter() - rt0
                    if tr:
                        tr.emit("prefill", req=r.job_id, tenant=r.tenant,
                                slot=r.slot, prompt_len=len(r.prompt),
                                dur_s=rdt)
                    if prof:
                        # contiguous prefill jits one program per prompt
                        # length — seq is the static half of the signature.
                        prof.record("prefill", rdt, seq=len(r.prompt),
                                    tokens=len(r.prompt),
                                    tenants={r.tenant: 1}, obs=c)
            if admitted:
                c.inc("prefill_s", time.perf_counter() - t0)
                state.set_rows(
                    [r.slot for r in admitted],
                    [r.output[-1] for r in admitted],
                    [len(r.prompt) for r in admitted],
                    [len(r.prompt) + r.max_new_tokens - 1 for r in admitted])
            self._evict(sched, state, c)  # satisfied by prefill alone / EOS
            if not sched.active:
                nxt = sched.next_arrival()
                if nxt is None:
                    break
                if self.injector is not None and nxt <= sched.step:
                    # everything waiting is held (a slowdown/storm window):
                    # jump to the next event that could unstall admission.
                    unb = self._next_unblock(sched)
                    nxt = unb if unb is not None else sched.step + 1
                sched.step = max(sched.step + 1, int(math.ceil(nxt)))
                if tr:
                    tr.step = sched.step
                continue

            # pool.write's eager scatter loses the NamedSharding layout;
            # restore it only on rounds that actually admitted (the
            # horizon's out_shardings keeps the cache sharded otherwise).
            if self.sharding is not None and admitted:
                pool.buffers = self.sharding.reshard_cache(pool.buffers)

            h = self._pick_h(sched, sorted(sched.active))
            self._decode_boundary(sched, pool, state, c, n_slots,
                                  self._dmult, h)
        self._evict(sched, state, c)

    # -- paged loop --------------------------------------------------------------
    def _next_lane_req(self, queue: deque, lanes) -> ServeRequest:
        """Pick the next request to fill a freed prefill lane.

        With a tenant allocation and a mixed-tenant queue, a tenant
        already holding its lane share (``allocation.lane_share``) yields
        the lane to the first queued request of an under-share tenant —
        a burst of one tenant's long prompts cannot monopolize every lane
        while another tenant's request waits. Work-conserving: when every
        queued tenant sits at its share (or the queue is single-tenant)
        the head proceeds anyway, so lanes never idle. Lane order only —
        outputs are unchanged (prefill is per-request exact-length)."""
        if self.allocation is None or len(queue) == 1:
            return queue.popleft()
        held = Counter(ln.req.tenant for ln in lanes)
        if len({r.tenant for r in queue} | set(held)) <= 1:
            return queue.popleft()
        for i, r in enumerate(queue):
            if held[r.tenant] < self.allocation.lane_share(r.tenant):
                del queue[i]
                return r
        return queue.popleft()

    def _batched_paged_prefill(self, pool: BlockManager, reqs, step: int,
                               c: RunObs) -> None:
        """Prefill all joining requests through up to ``prefill_lanes``
        lanes in lockstep chunk-rounds: one jitted ``[P, block_size]``
        dispatch per round covers one chunk of every live lane. A lane
        starts at its request's first non-cached position (prefix hits skip
        both blocks and compute), commits each completed full block to the
        prefix cache, and on its final chunk samples the request's first
        token from its last-valid-position logits; the freed lane is then
        refilled from the queue so long prompts never serialize behind
        short ones."""
        if not reqs:
            return
        bs, mb = pool.block_size, pool.max_blocks
        is_moe = self.cfg.family == "moe"
        cap_static = self.max_len if is_moe else 0
        if is_moe:
            from repro.models.moe import capacity as moe_capacity
        queue = deque(reqs)
        lanes: List[_PrefillLane] = []
        tr = c.tracer
        prof = self.profiler
        while queue or lanes:
            while queue and len(lanes) < self.prefill_lanes:
                r = self._next_lane_req(queue, lanes)
                prompt = np.asarray(r.prompt, np.int32)
                state = pool.resume_state(r.slot)
                if is_moe and state is None:
                    state = np.asarray(self.model.paged_prefill_state(1))
                lanes.append(_PrefillLane(
                    req=r, prompt=prompt, ptr=pool.cached_tokens(r.slot),
                    cap_row=(moe_capacity(self.cfg, len(prompt))
                             if is_moe else 0),
                    state=state))
            w = _bucket(len(lanes), self.prefill_lanes)
            tokens = np.zeros((w, bs), np.int32)
            starts = np.zeros((w,), np.int32)
            nv = np.zeros((w,), np.int32)
            caps = np.zeros((w,), np.int32)
            tables = np.full((w, mb), -1, np.int32)
            for i, ln in enumerate(lanes):
                n = min(bs, len(ln.prompt) - ln.ptr)
                tokens[i, :n] = ln.prompt[ln.ptr:ln.ptr + n]
                starts[i], nv[i], caps[i] = ln.ptr, n, ln.cap_row
                tables[i] = pool.tables[ln.req.slot]
            state = None
            if is_moe:
                cols = [ln.state for ln in lanes]
                cols += [np.zeros_like(cols[0])] * (w - len(lanes))
                state = jnp.asarray(np.concatenate(cols, axis=1))
            rt0 = time.perf_counter() if (tr or prof) else 0.0
            logits, pool.buffers, new_state = self._prefill(
                self.params, pool.buffers, jnp.asarray(tokens),
                jnp.asarray(starts), jnp.asarray(nv), jnp.asarray(tables),
                state, jnp.asarray(caps), cap=cap_static)
            c.inc("prefill_dispatches")
            if tr or prof:
                rdt = time.perf_counter() - rt0
                if tr:
                    tr.emit("prefill_round", lanes=len(lanes), width=w,
                            dur_s=rdt)
                if prof:
                    # one program per width bucket; padded lanes compute,
                    # so the roofline counts the full [w, bs] dispatch.
                    prof.record("prefill_round", rdt, width=w, tokens=w * bs,
                                kv_pos_sum=int(starts.sum()),
                                tenants=Counter(ln.req.tenant
                                                for ln in lanes), obs=c)
            if new_state is not None:
                new_state = np.asarray(new_state)
            done_idx: List[int] = []
            live: List[_PrefillLane] = []
            for i, ln in enumerate(lanes):
                n = int(nv[i])
                if new_state is not None:
                    ln.state = new_state[:, i:i + 1]
                if n == bs:        # a full block is final: cacheable
                    pool.commit_block(
                        ln.req.slot, ln.ptr // bs,
                        None if ln.state is None else ln.state.copy())
                ln.ptr += n
                if ln.ptr >= len(ln.prompt):
                    done_idx.append(i)
                else:
                    live.append(ln)
            if done_idx:
                slots = [lanes[i].req.slot for i in done_idx]
                toks = self._select_tokens(
                    logits[np.asarray(done_idx), -1], slots, ~step, c)
                for t, i in zip(toks, done_idx):
                    lanes[i].req.output.append(int(t))
            lanes = live

    def _growth_blocks_needed(self, sched, pool: BlockManager, pos_np,
                              stop_np, h: int) -> int:
        """Fresh blocks a horizon of ``h`` steps would allocate across the
        active rows (each row writes positions [pos, min(pos+h, stop)))."""
        need = 0
        for s in sched.active:
            want = pool.blocks_for(min(int(pos_np[s]) + h, int(stop_np[s])))
            need += max(0, want - pool.owned_blocks(s))
        return need

    def _ensure_growth(self, sched, pool: BlockManager, pos_np, stop_np,
                       h: int, c: RunObs):
        """Guarantee blocks for up to ``h`` decode tokens per active row
        before a horizon dispatch (the host cannot intervene mid-horizon).
        Shrinks the horizon toward 1 before resorting to preemption — a
        pool sized for the classic one-step loop still runs, just at
        shorter horizons — and preempts the most recently admitted request
        only while even one step cannot be covered.
        Returns (h, n_preempted, victim_slots)."""
        victims = []
        tr = c.tracer
        while True:
            h0 = h
            while h > 1 and (self._growth_blocks_needed(
                    sched, pool, pos_np, stop_np, h) > pool.free_blocks):
                h = max(1, h // 2)
            if tr and h < h0:
                tr.emit("horizon_shrink", from_k=h0, to_k=h,
                        cause="pool_pressure")
            blocked = next(
                (s for s in sorted(sched.active)
                 if not pool.ensure(s, min(int(pos_np[s]) + h,
                                           int(stop_np[s])))),
                None)
            if blocked is None:
                return h, len(victims), victims
            if len(sched.active) == 1:
                if self.injector is None and self.elastic is None:
                    raise RuntimeError(
                        "paged KV pool exhausted with a single active "
                        "request; grow n_blocks or lower max_new_tokens")
                # graceful horizon degradation: the budget vanished mid-
                # horizon (pool_shrink) under the LAST active request —
                # drop it instead of crashing the run.
                victim = sched.active[blocked]
                victims.append(victim.slot)
                sched.preempt(victim, cause="pool_exhausted")
                self._drop(sched, victim, c, cause="pool_exhausted")
                return h, len(victims), victims
            # victim choice: with a tenant registry the LARGEST SLO slack
            # goes first (a batch tenant without an SLO has infinite
            # slack), so pool pressure lands on whoever can absorb the
            # regeneration; without tenants, recency (the original rule).
            if self.tenants is not None:
                victim = max(sched.active.values(),
                             key=lambda r: (self._slack(r, sched.step),
                                            r.admitted_at, r.slot))
            else:
                victim = max(sched.active.values(),
                             key=lambda r: (r.admitted_at, r.slot))
            victims.append(victim.slot)
            sched.preempt(victim, cause="pool_pressure")

    def _run_paged(self, reqs, n_slots, c: RunObs):
        #: the pool outlives the run on ``self.pool`` so chaos tests and
        #: replay harnesses can audit block conservation after the fact
        self.pool = pool = BlockManager(self.model, n_slots, self.max_len,
                                        block_size=self.block_size,
                                        n_blocks=self.n_blocks,
                                        watermark=self.watermark,
                                        prefix_cache=self.prefix_cache,
                                        tracer=self.tracer)
        if self.sharding is not None:
            pool.buffers = self.sharding.reshard_cache(pool.buffers)
        if self.allocation is not None:
            # per-tenant watermark headroom: a tenant's admissions may
            # spend its OWN reserve (insensitive tenants donate theirs
            # implicitly — see BlockManager._blocks_clear_watermark).
            pool.tenant_reserves = self.allocation.reserves()
        sched = self._make_sched(pool)
        self._submit_all(sched, pool, reqs)

        state = _DecodeState(n_slots, max_blocks=pool.max_blocks,
                             sharding=self.sharding)
        pos_np = np.zeros((n_slots,), np.int64)
        stop_np = np.zeros((n_slots,), np.int64)
        tr = c.tracer
        peak_report = pool.report()

        while sched.has_work:
            if self.injector is not None:
                self._apply_faults(sched, pool, state, c, n_slots, reqs)
            self._elastic_poll(sched, pool, state, c)
            self._evict(sched, state, c)
            sched.admit(hold=self._fault_hold(sched))
            if self.injector is not None or self.elastic is not None:
                self._chaos_admission(sched, pool, c)
            admitted = sched.drain_prefill()
            if admitted:
                t0 = time.perf_counter()
                self._batched_paged_prefill(pool, admitted, sched.step, c)
                c.inc("prefill_s", time.perf_counter() - t0)
                for r in admitted:
                    pos_np[r.slot] = len(r.prompt)
                    stop_np[r.slot] = len(r.prompt) + r.max_new_tokens - 1
                    if (self.eos_token is not None
                            and r.output[-1] == self.eos_token):
                        r.finished_early = True
                slots = [r.slot for r in admitted]
                state.set_rows(slots,
                               [r.output[-1] for r in admitted],
                               [int(pos_np[s]) for s in slots],
                               [int(stop_np[s]) for s in slots])
                snap = pool.report()     # pool pressure peaks can be
                                         # prefill-only (max_new == 1 runs)
                if snap["used_blocks"] >= peak_report["used_blocks"]:
                    peak_report = snap
            self._evict(sched, state, c)  # satisfied by prefill alone / EOS
            if not sched.active:
                nxt = sched.next_arrival()
                if nxt is None:
                    break
                if not admitted and nxt <= sched.step:
                    if self.injector is None and self.elastic is None:
                        raise RuntimeError(
                            "paged KV pool cannot admit any waiting request; "
                            "grow n_blocks or lower the watermark")
                    # graceful degradation: a shrink/hold made everything
                    # momentarily inadmissible — advance to the next event
                    # that could unstall (hold release, backoff retry,
                    # pending fault, later arrival); retries bound the
                    # stall, dropping what the pool can never hold.
                    unb = self._next_unblock(sched)
                    nxt = unb if unb is not None else sched.step + 1
                sched.step = max(sched.step + 1, int(math.ceil(nxt)))
                if tr:
                    tr.step = sched.step
                continue

            if self.sharding is not None and admitted:
                pool.buffers = self.sharding.reshard_cache(pool.buffers)

            h = self._pick_h(sched, sorted(sched.active))
            h, n_pre, victims = self._ensure_growth(sched, pool, pos_np,
                                                    stop_np, h, c)
            c.inc("preemptions", n_pre)
            state.freeze(victims)
            if not sched.active:    # chaos: sole request dropped on
                continue            # exhaustion — back to admission
            # delta-sync the device table mirror: only rows dirtied by
            # admission / growth (freed rows stay stale — they are frozen
            # and write-masked, so the staleness is unobservable).
            dirty = [s for s in pool.drain_dirty() if s in sched.active]
            if dirty:
                state.set_tables(dirty, pool.tables[np.asarray(dirty)])

            act = sorted(sched.active)
            counts = self._decode_boundary(sched, pool, state, c, n_slots,
                                           self._dmult, h)
            for slot, m in zip(act, counts):
                pos_np[slot] += m
            snap = pool.report()
            if snap["used_blocks"] >= peak_report["used_blocks"]:
                peak_report = snap          # report the pool at peak pressure
        self._evict(sched, state, c)
        c.block_report = peak_report
        c.inc("prefix_hits", pool.prefix_blocks_hit)
        c.inc("prefix_total", pool.prefix_blocks_total)

    def generate(self, requests: List[ServeRequest]) -> List[ServeRequest]:
        """Run a batch of requests to completion; returns them."""
        return self.run(requests)[0]


def serve_step_fn(cfg: ArchConfig):
    """The (params, cache, tokens, pos) -> (logits, cache) step the dry-run
    lowers for decode shapes."""
    model = build_model(cfg)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step
