"""Deterministic fault injection for the serve engine.

The robustness half of ROADMAP item 5: Synergy's scheduling claims only
matter if the engine survives what multi-tenant clusters actually produce —
Jeon et al.'s Philly analysis (arXiv:1901.05758) shows failures, preemptions
and bursty arrivals dominate cluster behavior, and the gap Gao et al.
(arXiv:2205.11913) names between simulated and deployed schedulers is
exactly fault tolerance. This module provides the injection side; the
recovery paths live in the engine (regenerate-on-loss, retry-with-backoff,
graceful horizon degradation) and the block pool (``BlockManager.shrink`` /
``flush_prefix`` / ``audit``).

Faults are keyed to the engine's *decode-step clock*, not wall time: a
``Fault`` fires at the first horizon boundary whose step is >= its
``step``, and the engine caps horizon length at the next pending fault so
boundaries land promptly. Combined with a seeded RNG for every stochastic
choice (burst prompt content, slot-kill victim selection), a
``FaultSchedule`` replay is fully deterministic — the same schedule against
the same workload produces the same event trace twice, which is what lets
chaos runs assert the exactness invariant (greedy outputs token-identical
to a fault-free K=1 reference for every non-dropped request).

Fault taxonomy (``FAULT_KINDS``):

=================  ==========================================================
``pool_shrink``    ``blocks`` KV blocks revoked from the ``BlockManager``
                   mid-run (a co-tenant claims the memory); optionally
                   returned after ``restore_after`` steps.
``slot_kill``      a live slot's device state is declared lost; the engine
                   recovers by preempt-and-regenerate (token-identical).
``tenant_slowdown``  admission of one tenant's requests stalls for
                   ``duration`` steps (a slow/misbehaving tenant).
``arrival_burst``  ``n_requests`` synthetic requests (seeded content)
                   arrive at once on top of the open-loop trace.
``prefix_flush``   every prefix-cache entry is force-evicted; entries still
                   referenced by live requests are *retired* (unhittable,
                   freed when their last holder exits).
``defer_storm``    ALL admission stalls for ``duration`` steps (an
                   admission-control brownout).
``device_fail``    a device leaves the serving mesh: the engine shrinks the
                   block pool by ``blocks`` AND narrows the mesh 'data'
                   bucketing multiple (decode buckets fall back to
                   replicated layouts); optionally undone by an
                   auto-scheduled ``device_join`` after ``restore_after``.
``device_join``    a device (re)joins the mesh: pool capacity returns —
                   growing PAST the original allocation when the join
                   exceeds what a failure revoked (``BlockManager.
                   grow_physical`` migrates live KV blocks into the larger
                   buffers) — and the 'data' bucketing multiple is restored.
=================  ==========================================================

``pool_restore`` is the internal inverse of ``pool_shrink`` (auto-scheduled
by ``restore_after``, or usable directly in a schedule); ``device_join`` is
likewise the inverse ``device_fail`` auto-schedules. ``pending_capacity``
sums the blocks those pending inverses will return — the engine's admission
path holds (rather than drops) requests that fit the pool *plus* that
incoming capacity.
"""
from __future__ import annotations

import bisect
import json
import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

#: the injectable fault kinds (plus the internal pool_restore inverse)
FAULT_KINDS = ("pool_shrink", "slot_kill", "tenant_slowdown",
               "arrival_burst", "prefix_flush", "defer_storm",
               "device_fail", "device_join")
_ALL_KINDS = FAULT_KINDS + ("pool_restore",)

#: fault kinds whose pending application RETURNS pool capacity (the engine
#: holds — instead of drops — requests that fit current + pending blocks)
_CAPACITY_KINDS = ("pool_restore", "device_join")

#: spec-key -> (attribute, parser) for the ``kind@step:key=val`` grammar
_SPEC_KEYS = {
    "blocks": ("blocks", int),
    "slot": ("slot", int),
    "tenant": ("tenant", str),
    "duration": ("duration", float),
    "n": ("n_requests", int),
    "prompt_len": ("prompt_len", int),
    "max_new": ("max_new", int),
    "restore_after": ("restore_after", float),
}


@dataclass(frozen=True)
class Fault:
    """One scheduled fault: a kind, the step-clock key it fires at, and the
    kind-specific magnitude fields (unused fields are ignored)."""
    kind: str
    step: float
    blocks: int = 4                    # pool_shrink / pool_restore
    slot: Optional[int] = None         # slot_kill: None = seeded pick
    tenant: Optional[str] = None       # tenant_slowdown / arrival_burst tag
    duration: float = 8.0              # tenant_slowdown / defer_storm window
    n_requests: int = 4                # arrival_burst size
    prompt_len: int = 12               # arrival_burst prompt cap
    max_new: int = 8                   # arrival_burst generation budget
    restore_after: Optional[float] = None   # pool_shrink: steps until return

    def __post_init__(self):
        if self.kind not in _ALL_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {sorted(_ALL_KINDS)}")
        if self.kind == "tenant_slowdown" and self.tenant is None:
            raise ValueError("tenant_slowdown needs tenant=<id>")

    @classmethod
    def from_spec(cls, spec: str) -> "Fault":
        """Parse one ``kind@step[:key=val[:key=val...]]`` spec, e.g.
        ``pool_shrink@12:blocks=4:restore_after=20`` or ``slot_kill@8``."""
        head, _, tail = spec.strip().partition(":")
        kind, at, step = head.partition("@")
        if not at:
            raise ValueError(f"fault spec {spec!r} needs kind@step")
        kw: dict = {}
        for part in filter(None, tail.split(":")):
            key, eq, val = part.partition("=")
            if not eq or key not in _SPEC_KEYS:
                raise ValueError(f"bad fault spec field {part!r} in {spec!r};"
                                 f" known keys: {sorted(_SPEC_KEYS)}")
            attr, parse = _SPEC_KEYS[key]
            kw[attr] = parse(val)
        return cls(kind=kind.strip(), step=float(step), **kw)


@dataclass
class FaultSchedule:
    """A declarative, seeded list of faults. ``seed`` drives every
    stochastic choice the injector makes, so the schedule fully determines
    the chaos a replay sees."""
    faults: List[Fault] = field(default_factory=list)
    seed: int = 0

    @classmethod
    def from_spec(cls, spec: str, seed: int = 0) -> "FaultSchedule":
        """Comma-separated ``Fault.from_spec`` specs, e.g.
        ``"slot_kill@8,pool_shrink@16:blocks=6,defer_storm@24:duration=4"``."""
        faults = [Fault.from_spec(s) for s in spec.split(",") if s.strip()]
        return cls(faults=faults, seed=seed)

    @classmethod
    def from_json(cls, path: str) -> "FaultSchedule":
        """Load ``{"seed": ..., "faults": [{...}, ...]}`` from a file."""
        with open(path) as f:
            doc = json.load(f)
        return cls(faults=[Fault(**f) for f in doc.get("faults", [])],
                   seed=int(doc.get("seed", 0)))

    def to_json(self) -> dict:
        return {"seed": self.seed,
                "faults": [{"kind": f.kind, "step": f.step,
                            "blocks": f.blocks, "slot": f.slot,
                            "tenant": f.tenant, "duration": f.duration,
                            "n_requests": f.n_requests,
                            "prompt_len": f.prompt_len,
                            "max_new": f.max_new,
                            "restore_after": f.restore_after}
                           for f in self.faults]}


class FaultInjector:
    """Seeded, step-clock-keyed fault source the engine polls at horizon
    boundaries.

    The injector owns the *schedule* side of chaos — which fault is due,
    the seeded RNG behind victim picks and burst content, and the
    admission-hold windows ``tenant_slowdown`` / ``defer_storm`` open. The
    engine owns the *application* side (it holds the scheduler, pool and
    device state) and the recovery paths. ``reset()`` re-arms everything
    from (schedule, seed); the engine calls it at the top of every ``run``
    so warm-up double-runs and determinism checks replay identical chaos.
    """

    def __init__(self, schedule: FaultSchedule, seed: Optional[int] = None):
        self.schedule = schedule
        self.seed = schedule.seed if seed is None else int(seed)
        self.vocab_size = 2            # rebound by the engine (bind())
        self.max_len = 64
        self.n_slots = 1
        self.reset()

    def bind(self, *, vocab_size: int, max_len: int, n_slots: int) -> None:
        """Engine geometry for burst generation / victim picks."""
        self.vocab_size = int(vocab_size)
        self.max_len = int(max_len)
        self.n_slots = int(n_slots)

    def reset(self) -> None:
        """Re-arm the schedule and re-seed the RNG (start of every run)."""
        self.rng = np.random.default_rng(self.seed)
        #: pending faults in (step, schedule-order) — stable sort keeps
        #: same-step faults in declaration order
        self._pending: List[Fault] = sorted(
            self.schedule.faults, key=lambda f: f.step)
        self._steps: List[float] = [f.step for f in self._pending]
        #: admission holds: tenant id (None = global) -> hold-until step
        self._holds: Dict[Optional[str], float] = {}
        #: applied-fault log (kind, step) — mirrors the fault_inject events
        self.injected: List[Tuple[str, float]] = []

    # -- schedule queries (the engine's boundary hooks) ----------------------
    def next_fault_step(self, step: float) -> Optional[float]:
        """The earliest pending fault step strictly after ``step`` (the
        engine caps horizon length here so boundaries land on faults)."""
        for s in self._steps:
            if s > step:
                return s
        return None

    def due(self, step: float) -> List[Fault]:
        """Pop every pending fault with ``fault.step <= step``."""
        i = bisect.bisect_right(self._steps, step)
        out, self._pending = self._pending[:i], self._pending[i:]
        self._steps = self._steps[i:]
        return out

    def defer_restore(self, fault: Fault, applied_step: float,
                      blocks: int) -> None:
        """Schedule the kind-appropriate inverse of an applied capacity
        loss: ``pool_restore`` for a ``pool_shrink``, ``device_join`` for a
        ``device_fail`` (the join must also widen the mesh bucketing, which
        a plain restore does not)."""
        inverse = "device_join" if fault.kind == "device_fail" \
            else "pool_restore"
        restore = replace(fault, kind=inverse, blocks=blocks,
                          step=applied_step + float(fault.restore_after),
                          restore_after=None)
        i = bisect.bisect_right(self._steps, restore.step)
        self._pending.insert(i, restore)
        self._steps.insert(i, restore.step)

    def pending_capacity(self, step: float) -> int:
        """KV blocks that pending ``pool_restore`` / ``device_join`` faults
        strictly after ``step`` will hand back — the capacity an admission
        decision may count on arriving (the hold-don't-drop window)."""
        return sum(f.blocks for f in self._pending
                   if f.step > step and f.kind in _CAPACITY_KINDS)

    # -- admission holds ------------------------------------------------------
    def hold(self, tenant: Optional[str], until: float) -> None:
        """Open (or extend) an admission-hold window; ``tenant=None`` holds
        every tenant (defer_storm)."""
        self._holds[tenant] = max(self._holds.get(tenant, -math.inf), until)

    def has_holds(self, step: float) -> bool:
        self._holds = {t: u for t, u in self._holds.items() if u > step}
        return bool(self._holds)

    def hold_cause(self, req, step: float) -> Optional[str]:
        """Why ``req`` must wait this round (None = admissible): the global
        storm outranks per-tenant slowdowns in the emitted cause."""
        if self._holds.get(None, -math.inf) > step:
            return "defer_storm"
        if self._holds.get(req.tenant, -math.inf) > step:
            return "tenant_slowdown"
        return None

    def release_step(self, step: float) -> Optional[float]:
        """The earliest hold expiry strictly after ``step``."""
        later = [u for u in self._holds.values() if u > step]
        return min(later) if later else None

    # -- seeded choices -------------------------------------------------------
    def pick_slot(self, live_slots: List[int],
                  want: Optional[int] = None) -> Optional[int]:
        """The slot a ``slot_kill`` lands on: the requested slot when it is
        live, else a seeded uniform pick (None when nothing is live)."""
        if not live_slots:
            return None
        if want is not None and want in live_slots:
            return want
        order = sorted(live_slots)
        return order[int(self.rng.integers(len(order)))]

    def burst_requests(self, fault: Fault) -> list:
        """Synthetic requests for an ``arrival_burst``: seeded prompt
        content sized to the bound engine geometry (job ids and arrival
        steps are stamped by the engine at application time)."""
        from repro.serve.scheduler import ServeRequest
        cap = max(1, min(fault.prompt_len, self.max_len - fault.max_new))
        out = []
        for _ in range(max(1, fault.n_requests)):
            n = int(self.rng.integers(max(1, cap // 2), cap + 1))
            toks = self.rng.integers(
                1, max(2, self.vocab_size), size=n).astype(np.int32)
            out.append(ServeRequest(prompt=toks,
                                    max_new_tokens=fault.max_new,
                                    tenant=fault.tenant or "default"))
        return out
