"""Block-table KV manager: length-proportional cache allocation.

The serving mirror of Synergy's memory-sensitivity argument (PAPER.md §4):
`CachePool` gives every request a full ``max_len`` cache row — the
GPU-proportional over-allocation the paper argues against. ``BlockManager``
instead carves one ``[n_blocks, block_size, ...]`` buffer per cache leaf into
fixed-size blocks: a request at length L holds exactly ``ceil(L /
block_size)`` blocks behind a per-request block table, so a 40-token prompt
in a 256-position pool costs 3 blocks of 16 instead of a 256-row.

Admission is watermark-based: a request is admitted when its *prompt* blocks
fit while keeping ``watermark * n_blocks`` blocks free as decode-growth
headroom. Growth (``ensure``) may eat into the reserve; when the pool is
truly out of blocks the engine preempts the most recently admitted request
(its blocks are freed and its tokens regenerated identically after
re-admission — prefill is deterministic).

Blocks and decode slots are both recycled FIFO, mirroring ``CachePool``'s
recycling discipline, and a freed request's table row is cleared to -1 so a
re-issued block can never be read through a stale table.

Prefix caching (``prefix_cache=True``) adds a content-addressed layer on
top: every FULL prompt block is identified by a rolling hash of its tokens
chained to its predecessor's hash, so "same hash" implies "same prompt
prefix" and therefore — prefill being deterministic — identical K/V
content. A request whose leading hashes are already cached *shares* those
blocks (ref-counted) and skips both their allocation and their prefill
compute; only the unshared suffix is computed. The partial tail block is
always privately allocated (copy-on-write discipline: shared blocks are
never written after their owner's prefill, appends land in fresh blocks),
so a tenant's decode can never corrupt a neighbour's prefix. Blocks whose
refcount drops to zero stay cached in an *evictable* FIFO and are only
reclaimed when the free list runs dry — a re-arriving prefix revives them
for free. For MoE, the per-layer expert-assignment counts after each block
are snapshotted alongside the hash (and the routing capacity is folded into
the hash seed), so a prefix-hit resume routes token-for-token like a cold
prefill.
"""
from __future__ import annotations

import hashlib
import math
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import NULL_TRACER


@dataclass
class _PrefixEntry:
    """One cached full prompt block: hash -> (block id, refcount, state).

    ``ready`` flips when the owning request's prefill has actually written
    the block's K/V (``commit_block``); a hit on an unready entry defers the
    hitting request instead of reading half-written content. ``state`` is
    the family's cross-chunk prefill carry *after* this block (MoE expert
    counts; None for dense/vlm). ``retired`` marks an entry force-flushed
    (``flush_prefix``) while still referenced: it stays for refcounting but
    is unhittable, and its block is released when the last holder frees —
    deleting it outright would double-free the block (every sharer's
    ``free`` would see a private block and return it to the free list).
    """
    block: int
    refs: int = 0
    ready: bool = False
    state: object = field(default=None, repr=False)
    retired: bool = False


class BlockManager:
    """Paged decode cache over a model's ``init_paged_cache`` pytree.

    Exposes the pool surface ``ContinuousScheduler`` drives — ``alloc_for`` /
    ``free`` / ``max_len`` / ``validate_request`` — plus the block-granular
    calls the paged engine uses per step (``ensure``, ``table_rows``,
    ``report``) and the prefix-cache surface (``cached_tokens``,
    ``resume_state``, ``commit_block``).
    """

    def __init__(self, model, n_slots: int, max_len: int,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 watermark: float = 0.05, dtype=None,
                 prefix_cache: bool = False, tracer=NULL_TRACER):
        if model.init_paged_cache is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged decode cache "
                "(recurrent state is O(1); use the contiguous CachePool)")
        self.model = model
        #: obs.Tracer for block-pool events (alloc / grow / free /
        #: prefix_evict); the engine's clock is inherited via ``tracer.step``.
        #: The falsy NULL_TRACER default keeps every emission one branch.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_size = block_size
        self.max_blocks = -(-max_len // block_size)   # table width per slot
        #: default pool capacity == the contiguous pool's token capacity
        self.n_blocks = (n_blocks if n_blocks is not None
                         else n_slots * self.max_blocks)
        self.watermark = float(watermark)   # fraction; re-applied on shrink
        self.watermark_blocks = math.ceil(watermark * self.n_blocks)
        #: fault injection (chaos.FaultInjector pool_shrink): blocks revoked
        #: from the pool mid-run, a deficit still owed from in-use blocks,
        #: and the buffer capacity audits reconcile against.
        self._revoked: List[int] = []
        self._revoke_deficit = 0
        self._total_blocks = self.n_blocks
        #: per-tenant watermark headroom (tenant.TenantAllocation.reserves):
        #: when set, a tenant admitting must keep only the OTHER tenants'
        #: reserve free — its own headroom is admission-spendable, so
        #: insensitive tenants' headroom is effectively stolen by the
        #: sensitive ones the allocator favoured. Empty dict = the flat
        #: single-watermark rule.
        self.tenant_reserves: Dict[str, int] = {}
        self._dtype = dtype            # kept for grow_physical reallocation
        self._block_axes = None        # leaf block-axis map, probed lazily
        self.buffers = model.init_paged_cache(self.n_blocks, block_size,
                                              dtype)
        self._free_blocks = deque(range(self.n_blocks))
        self._free_slots = deque(range(n_slots))
        self._in_use: set = set()
        self.tables = np.full((n_slots, self.max_blocks), -1, np.int32)
        self._lengths = np.zeros((n_slots,), np.int64)  # tokens owned
        # -- prefix cache ----------------------------------------------------
        self.prefix_cache = prefix_cache
        self._entries: Dict[int, _PrefixEntry] = {}       # hash -> entry
        self._evictable: "OrderedDict[int, None]" = OrderedDict()  # FIFO
        #: per-slot chain of (hash | None, owned) for the prompt's full
        #: blocks; None marks a private block (hash already owned elsewhere)
        self._chains: Dict[int, List[Tuple[Optional[int], bool]]] = {}
        self._cached_tokens = np.zeros((n_slots,), np.int64)
        self._resume: Dict[int, object] = {}
        self.prefix_blocks_total = 0   # full+partial prompt blocks allocated
        self.prefix_blocks_hit = 0     # of those, served from the cache
        #: True iff the LAST alloc_for returned None because a donor was
        #: still prefilling (vs pool exhaustion) — the scheduler admits
        #: unrelated requests past a deferral but stops on exhaustion.
        self.deferred_last_alloc = False
        #: slots whose table row changed since the last ``drain_dirty`` —
        #: the engine mirrors the block tables on device between decode
        #: horizons and only re-uploads the dirty rows (delta updates at
        #: admission / growth / free instead of per-step re-upload).
        self._dirty_slots: set = set()

    # -- block math ----------------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.block_size)

    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def free_blocks(self) -> int:
        """Blocks available to allocation: truly free + evictable cached."""
        return len(self._free_blocks) + len(self._evictable)

    @property
    def evictable_blocks(self) -> int:
        return len(self._evictable)

    @property
    def in_use(self):
        return frozenset(self._in_use)

    # -- prefix hashing ------------------------------------------------------
    def _hash_chain(self, prompt: np.ndarray) -> List[int]:
        """Rolling content hashes of the prompt's FULL blocks. The seed folds
        in the routing capacity for MoE (two prompts sharing tokens but not
        capacity must not share blocks — capacity drops would differ)."""
        salt = 0
        if self.model.cfg.family == "moe":
            from repro.models.moe import capacity
            salt = capacity(self.model.cfg, len(prompt))
        prev = salt.to_bytes(8, "little", signed=True)
        hashes = []
        for i0 in range(0, (len(prompt) // self.block_size) * self.block_size,
                        self.block_size):
            h = hashlib.blake2b(
                prev + np.ascontiguousarray(
                    prompt[i0:i0 + self.block_size], np.int64).tobytes(),
                digest_size=16).digest()
            hashes.append(int.from_bytes(h, "little"))
            prev = h
        return hashes

    def _take_block(self) -> int:
        """A free block, evicting the oldest refcount-0 cached block if the
        free list is dry (its hash entry is dropped: content unreachable)."""
        if self._free_blocks:
            return self._free_blocks.popleft()
        h, _ = self._evictable.popitem(last=False)
        if self.tracer:
            self.tracer.emit("prefix_evict", blocks=1)
        return self._entries.pop(h).block

    def _release_block(self, blk: int) -> None:
        """Return a block to the pool — or to a pending revocation: after a
        ``shrink`` that could not find enough idle blocks, the deficit is
        collected here as in-use blocks come back."""
        if self._revoke_deficit > 0:
            self._revoke_deficit -= 1
            self._revoked.append(blk)
        else:
            self._free_blocks.append(blk)

    # -- admission -----------------------------------------------------------
    def validate_request(self, req) -> None:
        """Reject requests that can never run on this pool."""
        need = len(req.prompt) + req.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"request needs {need} cache positions but the pool's block "
                f"tables span {self.max_len}")
        if self.blocks_for(need) > self.n_blocks:
            raise ValueError(
                f"request needs {self.blocks_for(need)} blocks but the pool "
                f"holds {self.n_blocks}")
        if self.blocks_for(len(req.prompt)) + self.watermark_blocks \
                > self.n_blocks:
            raise ValueError(
                f"prompt needs {self.blocks_for(len(req.prompt))} blocks "
                f"which can never clear the {self.watermark_blocks}-block "
                f"admission watermark on a {self.n_blocks}-block pool")

    def _blocks_clear_watermark(self, n_new_blocks: int,
                                tenant: Optional[str] = None) -> bool:
        """The watermark rule: ``n_new_blocks`` fresh blocks fit while the
        reserve stays free (``can_admit`` and ``alloc_for`` must agree —
        alloc_for charges only the non-cached blocks). With per-tenant
        reserves installed, a known tenant only keeps the OTHER tenants'
        headroom free — its own share of the reserve is spendable at its
        admission."""
        reserve = self.watermark_blocks
        if tenant is not None and tenant in self.tenant_reserves:
            reserve = min(reserve,
                          sum(self.tenant_reserves.values())
                          - self.tenant_reserves[tenant])
        return self.free_blocks - n_new_blocks >= reserve

    def can_admit(self, n_tokens: int) -> bool:
        """Watermark admission: prompt blocks fit AND the high-watermark
        reserve stays free for decode growth of already-admitted tenants.
        (Cache-blind: a prompt with cached prefix blocks may be admissible
        even when this returns False — ``alloc_for`` is the authority.)"""
        return (bool(self._free_slots)
                and self._blocks_clear_watermark(self.blocks_for(n_tokens)))

    def alloc_for(self, req) -> Optional[int]:
        """Admit ``req``: claim a slot + its prompt's blocks; None if the
        watermark would be violated (the scheduler keeps it queued).

        With the prefix cache on, the prompt's leading full blocks are
        looked up by content hash: ready hits are *shared* (refcount++, no
        new block, no prefill compute — ``cached_tokens`` tells the engine
        where to resume); a hit on a block another tenant is still
        prefilling returns None, deferring the request one round so it can
        share the finished block instead of racing the writer. The last
        chunk is never served from cache — its logits seed the first
        generated token."""
        n = len(req.prompt)
        need = self.blocks_for(n)
        hashes: List[int] = []
        hits = revived = 0
        self.deferred_last_alloc = False
        if self.prefix_cache:
            # the chain is pure content: memoize it on the (immutable-prompt)
            # request so per-step admission retries do not rehash.
            memo_key = (self.block_size, self.model.cfg.arch_id)
            memo = getattr(req, "_prefix_hashes", None)
            if memo is not None and memo[0] == memo_key:
                hashes = memo[1]
            else:
                hashes = self._hash_chain(np.asarray(req.prompt))
                req._prefix_hashes = (memo_key, hashes)
            hit_cap = (n - 1) // self.block_size
            for idx, h in enumerate(hashes[:hit_cap]):
                e = self._entries.get(h)
                if e is None or e.retired:   # retired = flushed, unhittable
                    break
                if not e.ready:
                    # donor mid-prefill: join next round (the scheduler may
                    # still admit unrelated requests this round)
                    self.deferred_last_alloc = True
                    return None
                hits += 1
                # a refcount-0 hit revives a block ``free_blocks`` counts
                # as available: it costs no NEW block but still shrinks
                # availability, so charge it or the private-suffix take
                # below can run the pool dry mid-allocation.
                revived += e.refs == 0
        if (not self._free_slots
                or not self._blocks_clear_watermark(
                    need - hits + revived, getattr(req, "tenant", None))):
            return None
        slot = self._free_slots.popleft()
        self._in_use.add(slot)
        chain: List[Tuple[Optional[int], bool]] = []
        for j in range(need):
            if j < hits:
                e = self._entries[hashes[j]]
                if e.refs == 0:
                    self._evictable.pop(hashes[j], None)
                e.refs += 1
                self.tables[slot, j] = e.block
                chain.append((hashes[j], False))
            else:
                self.tables[slot, j] = self._take_block()
                if self.prefix_cache and j < len(hashes):
                    if hashes[j] in self._entries:
                        chain.append((None, False))   # hash owned elsewhere
                    else:
                        self._entries[hashes[j]] = _PrefixEntry(
                            block=int(self.tables[slot, j]), refs=1)
                        chain.append((hashes[j], True))
        self._lengths[slot] = n
        self._dirty_slots.add(slot)
        if self.prefix_cache:
            self._chains[slot] = chain
            self._cached_tokens[slot] = hits * self.block_size
            self._resume[slot] = (self._entries[hashes[hits - 1]].state
                                  if hits else None)
            self.prefix_blocks_total += need
            self.prefix_blocks_hit += hits
        if self.tracer:
            self.tracer.emit("block_alloc", slot=slot, blocks=need - hits,
                             hits=hits)
        return slot

    # -- prefix-cache surface (engine prefill hooks) --------------------------
    def cached_tokens(self, slot: int) -> int:
        """Prompt positions already covered by cache hits: prefill resumes
        here (0 when the prefix cache is off or missed)."""
        return int(self._cached_tokens[slot])

    def resume_state(self, slot: int):
        """The cross-chunk prefill carry snapshotted after the last hit
        block (MoE expert counts), or None for a cold start."""
        return self._resume.get(slot)

    def commit_block(self, slot: int, block_idx: int, state=None) -> None:
        """Mark a prompt block's content written (the engine calls this as
        its prefill finishes each full block): the entry becomes hittable
        and carries the prefill state snapshot for MoE-exact resumes."""
        chain = self._chains.get(slot, ())
        if block_idx >= len(chain):
            return
        h, owned = chain[block_idx]
        if not owned or h is None:
            return
        e = self._entries.get(h)
        if e is not None and e.block == int(self.tables[slot, block_idx]):
            e.ready = True
            e.state = state

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to cover ``n_tokens`` positions (decode append).
        May eat into the watermark reserve; False when the pool is dry.
        Growth blocks are always private — appends never touch a shared
        prefix block (the copy-on-write discipline)."""
        if slot not in self._in_use:
            raise ValueError(f"slot {slot} is not allocated")
        have = have0 = int((self.tables[slot] >= 0).sum())
        while have * self.block_size < n_tokens:
            if not self._free_blocks and not self._evictable:
                return False
            self.tables[slot, have] = self._take_block()
            self._dirty_slots.add(slot)
            have += 1
        if self.tracer and have > have0:
            self.tracer.emit("block_grow", slot=slot, blocks=have - have0)
        self._lengths[slot] = max(self._lengths[slot], n_tokens)
        return True

    def owned_blocks(self, slot: int) -> int:
        """Blocks currently assigned to ``slot``'s table."""
        return int((self.tables[slot] >= 0).sum())

    def drain_dirty(self) -> set:
        """Slots whose table rows changed since the last drain (clears the
        set) — the engine's device-resident table mirror syncs these rows."""
        dirty, self._dirty_slots = self._dirty_slots, set()
        return dirty

    def free(self, slot: int) -> None:
        """Release a request's slot and blocks (FIFO recycle, stale table
        entries cleared so re-issued blocks are unreachable). Shared prefix
        blocks are only de-referenced: at refcount 0 they park in the
        evictable FIFO — still hittable — until the free list runs dry."""
        if slot not in self._in_use:
            raise ValueError(f"slot {slot} is not allocated")
        self._in_use.remove(slot)
        chain = self._chains.pop(slot, ())
        n_freed = n_shared = 0
        for j in range(self.max_blocks):
            blk = int(self.tables[slot, j])
            if blk < 0:
                continue
            n_freed += 1
            h = chain[j][0] if j < len(chain) else None
            e = self._entries.get(h) if h is not None else None
            if e is not None and e.block == blk:
                n_shared += 1
                e.refs -= 1
                if e.refs == 0:
                    if e.ready and not e.retired:
                        self._evictable[h] = None
                    else:   # owner bailed before writing, or force-flushed
                        # at nonzero refcount: unservable either way
                        del self._entries[h]
                        self._release_block(blk)
            else:
                self._release_block(blk)
        self.tables[slot] = -1
        self._dirty_slots.add(slot)
        self._lengths[slot] = 0
        self._cached_tokens[slot] = 0
        self._resume.pop(slot, None)
        self._free_slots.append(slot)
        if self.tracer:
            self.tracer.emit("block_free", slot=slot, blocks=n_freed,
                             shared=n_shared)

    # -- fault injection (chaos.FaultInjector recovery surface) --------------
    def shrink(self, n: int) -> int:
        """Revoke up to ``n`` blocks of capacity mid-run (a ``pool_shrink``
        fault: a co-tenant claims the memory). Idle blocks go first — the
        free list, then evictable cached blocks (their entries dropped) —
        and any remainder becomes a *deficit* collected as in-use blocks
        free (``_release_block``). Capacity accounting (``n_blocks``, the
        watermark) rescales immediately, so admission decisions see the
        shrunken pool at once; per-tenant reserves are the engine's to
        rescale (``TenantAllocation.rescaled_reserves``). At least one
        block of capacity always survives. Returns the blocks revoked."""
        take = max(0, min(int(n), self.n_blocks - 1))
        got = 0
        while got < take and (self._free_blocks or self._evictable):
            self._revoked.append(self._take_block())
            got += 1
        self._revoke_deficit += take - got
        self.n_blocks -= take
        self.watermark_blocks = math.ceil(self.watermark * self.n_blocks)
        return take

    def expand(self, n: int) -> int:
        """Return up to ``n`` previously revoked blocks (``pool_restore``).
        Deficit cancels first — those blocks never actually left the
        tables — then physically revoked blocks rejoin the free list."""
        give = min(int(n), len(self._revoked) + self._revoke_deficit)
        cancel = min(give, self._revoke_deficit)
        self._revoke_deficit -= cancel
        for _ in range(give - cancel):
            self._free_blocks.append(self._revoked.pop())
        self.n_blocks += give
        self.watermark_blocks = math.ceil(self.watermark * self.n_blocks)
        return give

    def grow_physical(self, n: int, sharding=None) -> int:
        """Grow TRUE capacity past the construction-time allocation (a
        ``device_join`` bringing more memory than any failure revoked):
        allocate larger cache buffers and migrate every existing block's
        content into them along each leaf's block axis — a pure state move,
        never a recompute, so in-flight decodes resume token-identically.
        ``sharding`` (the plan's ``cache_sharding`` pytree) re-places the
        migrated buffers on the mesh; the block axis is unsharded in the
        paged specs, so the same NamedShardings apply at any capacity.

        Block ids are stable — the new blocks take ids past the old
        capacity and join the free list — so live tables, prefix-cache
        entries and the revocation ledger all survive untouched. Returns
        the blocks added (0 for ``n <= 0``)."""
        import jax

        n = int(n)
        if n <= 0:
            return 0
        if self._block_axes is None:
            from repro.serve.cache import _batch_axis
            probe_a = jax.eval_shape(
                lambda: self.model.init_paged_cache(3, self.block_size,
                                                    self._dtype))
            probe_b = jax.eval_shape(
                lambda: self.model.init_paged_cache(5, self.block_size,
                                                    self._dtype))
            self._block_axes = jax.tree_util.tree_map(_batch_axis, probe_a,
                                                      probe_b)
        old_total = self._total_blocks
        new_buffers = self.model.init_paged_cache(old_total + n,
                                                  self.block_size,
                                                  self._dtype)

        def migrate(new, old, ax):
            sel = (slice(None),) * ax + (slice(0, old.shape[ax]),)
            return new.at[sel].set(old)

        new_buffers = jax.tree_util.tree_map(migrate, new_buffers,
                                             self.buffers, self._block_axes)
        if sharding is not None:
            new_buffers = jax.device_put(new_buffers, sharding)
        self.buffers = new_buffers
        self._free_blocks.extend(range(old_total, old_total + n))
        self._total_blocks = old_total + n
        self.n_blocks += n
        self.watermark_blocks = math.ceil(self.watermark * self.n_blocks)
        return n

    def flush_prefix(self) -> int:
        """Force-evict the prefix cache (a ``prefix_flush`` fault).
        Refcount-0 entries release their blocks immediately; entries still
        referenced by live requests are *retired* — unhittable for future
        admissions, their blocks released when the last holder frees.
        Returns entries flushed (freed + retired)."""
        freed = 0
        for h in list(self._evictable):
            del self._evictable[h]
            self._release_block(self._entries.pop(h).block)
            freed += 1
        retired = 0
        for e in self._entries.values():
            if not e.retired:
                e.retired = True
                retired += 1
        if freed and self.tracer:
            self.tracer.emit("prefix_evict", blocks=freed)
        return freed + retired

    def audit(self) -> Dict[str, int]:
        """Block-conservation check: every block the pool was built with is
        in exactly ONE of {free list, revoked, a table (counted once across
        sharers), evictable cache}, modulo the outstanding revocation
        deficit (those blocks sit in tables, owed). Also checks refcount
        agreement (an entry's refs equals its block's table multiplicity)
        and slot/table consistency. Raises RuntimeError on any violation —
        the engine asserts this after every injected fault — and returns a
        summary dict when clean."""
        problems: List[str] = []
        free = list(self._free_blocks)
        free_set = set(free)
        if len(free_set) != len(free):
            problems.append(f"duplicate blocks in the free list: {free}")
        revoked_set = set(self._revoked)
        if len(revoked_set) != len(self._revoked):
            problems.append(f"duplicate revoked blocks: {self._revoked}")
        if free_set & revoked_set:
            problems.append(f"free∩revoked: {sorted(free_set & revoked_set)}")
        # table multiplicity per block; idle slots must have empty tables
        table_refs: Dict[int, int] = {}
        for slot in range(self.n_slots):
            row = self.tables[slot]
            if slot not in self._in_use:
                if (row >= 0).any():
                    problems.append(f"idle slot {slot} holds table blocks")
                continue
            for blk in row[row >= 0]:
                table_refs[int(blk)] = table_refs.get(int(blk), 0) + 1
        table_set = set(table_refs)
        for name, other in (("free", free_set), ("revoked", revoked_set)):
            if table_set & other:
                problems.append(
                    f"table∩{name}: {sorted(table_set & other)}")
        # entry <-> table refcount agreement
        entry_blocks: Dict[int, int] = {}
        for h, e in self._entries.items():
            if e.block in entry_blocks:
                problems.append(f"two entries share block {e.block}")
            entry_blocks[e.block] = e.refs
            if e.refs != table_refs.get(e.block, 0):
                problems.append(
                    f"entry {h:#x} refs={e.refs} but block {e.block} has "
                    f"table multiplicity {table_refs.get(e.block, 0)}")
            if e.refs == 0 and h not in self._evictable:
                problems.append(
                    f"refcount-0 entry {h:#x} not in the evictable FIFO")
        for blk, cnt in table_refs.items():
            if cnt > 1 and blk not in entry_blocks:
                problems.append(
                    f"block {blk} shared by {cnt} tables without an entry")
        evict_blocks = {self._entries[h].block for h in self._evictable
                        if h in self._entries}
        missing = set(self._evictable) - set(self._entries)
        if missing:
            problems.append(f"evictable hashes without entries: "
                            f"{[hex(h) for h in missing]}")
        # the conservation sum: deficit blocks live in tables, still owed
        accounted = (len(free_set) + len(revoked_set) + len(table_set)
                     + len(evict_blocks - table_set))
        if accounted != self._total_blocks:
            problems.append(
                f"{accounted} blocks accounted for "
                f"(free={len(free_set)} revoked={len(revoked_set)} "
                f"table={len(table_set)} evictable={len(evict_blocks)}) "
                f"of {self._total_blocks}")
        if (self.n_blocks + len(self._revoked) + self._revoke_deficit
                != self._total_blocks):
            problems.append(
                f"capacity arithmetic broken: n_blocks={self.n_blocks} "
                f"+ revoked={len(self._revoked)} "
                f"+ deficit={self._revoke_deficit} != {self._total_blocks}")
        if problems:
            raise RuntimeError("block audit failed:\n  "
                               + "\n  ".join(problems))
        return {"free": len(free_set), "revoked": len(revoked_set),
                "deficit": self._revoke_deficit, "in_table": len(table_set),
                "evictable": len(evict_blocks),
                "capacity": self.n_blocks}

    # -- decode-step views ---------------------------------------------------
    def table_rows(self, slots) -> np.ndarray:
        """[len(slots), max_blocks] int32 block tables for a decode batch."""
        return self.tables[np.asarray(slots, np.int64)]

    # -- occupancy / fragmentation -------------------------------------------
    def report(self) -> Dict[str, float]:
        """Occupancy + fragmentation snapshot (CLI summary / tests). Shared
        blocks count once toward ``used_blocks`` but every tenant's tokens
        count toward ``used_tokens``, so fragmentation is clamped at 0."""
        used_blocks = self.n_blocks - self.free_blocks
        allocated = used_blocks * self.block_size
        used_tokens = int(self._lengths.sum())
        return {
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "used_blocks": used_blocks,
            "free_blocks": self.free_blocks,
            "evictable_blocks": self.evictable_blocks,
            "watermark_blocks": self.watermark_blocks,
            "occupancy": used_blocks / self.n_blocks if self.n_blocks else 0.0,
            "used_tokens": used_tokens,
            "allocated_tokens": allocated,
            # internal fragmentation: allocated-but-unused tail positions of
            # each tenant's last block.
            "internal_fragmentation": max(
                0.0, 1.0 - used_tokens / allocated) if allocated else 0.0,
            "prefix_blocks_total": self.prefix_blocks_total,
            "prefix_blocks_hit": self.prefix_blocks_hit,
            "revoked_blocks": len(self._revoked),
            "revoke_deficit": self._revoke_deficit,
        }
