"""Block-table KV manager: length-proportional cache allocation.

The serving mirror of Synergy's memory-sensitivity argument (PAPER.md §4):
`CachePool` gives every request a full ``max_len`` cache row — the
GPU-proportional over-allocation the paper argues against. ``BlockManager``
instead carves one ``[n_blocks, block_size, ...]`` buffer per cache leaf into
fixed-size blocks: a request at length L holds exactly ``ceil(L /
block_size)`` blocks behind a per-request block table, so a 40-token prompt
in a 256-position pool costs 3 blocks of 16 instead of a 256-row.

Admission is watermark-based: a request is admitted when its *prompt* blocks
fit while keeping ``watermark * n_blocks`` blocks free as decode-growth
headroom. Growth (``ensure``) may eat into the reserve; when the pool is
truly out of blocks the engine preempts the most recently admitted request
(its blocks are freed and its tokens regenerated identically after
re-admission — prefill is deterministic).

Blocks and decode slots are both recycled FIFO, mirroring ``CachePool``'s
recycling discipline, and a freed request's table row is cleared to -1 so a
re-issued block can never be read through a stale table.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Dict, Optional

import numpy as np


class BlockManager:
    """Paged decode cache over a model's ``init_paged_cache`` pytree.

    Exposes the pool surface ``ContinuousScheduler`` drives — ``alloc_for`` /
    ``free`` / ``max_len`` / ``validate_request`` — plus the block-granular
    calls the paged engine uses per step (``ensure``, ``table_rows``,
    ``report``).
    """

    def __init__(self, model, n_slots: int, max_len: int,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 watermark: float = 0.05, dtype=None):
        if model.init_paged_cache is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged decode cache "
                "(recurrent state is O(1); use the contiguous CachePool)")
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_size = block_size
        self.max_blocks = -(-max_len // block_size)   # table width per slot
        #: default pool capacity == the contiguous pool's token capacity
        self.n_blocks = (n_blocks if n_blocks is not None
                         else n_slots * self.max_blocks)
        self.watermark_blocks = math.ceil(watermark * self.n_blocks)
        self.buffers = model.init_paged_cache(self.n_blocks, block_size,
                                              dtype)
        self._free_blocks = deque(range(self.n_blocks))
        self._free_slots = deque(range(n_slots))
        self._in_use: set = set()
        self.tables = np.full((n_slots, self.max_blocks), -1, np.int32)
        self._lengths = np.zeros((n_slots,), np.int64)  # tokens owned

    # -- block math ----------------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.block_size)

    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def in_use(self):
        return frozenset(self._in_use)

    # -- admission -----------------------------------------------------------
    def validate_request(self, req) -> None:
        """Reject requests that can never run on this pool."""
        need = len(req.prompt) + req.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"request needs {need} cache positions but the pool's block "
                f"tables span {self.max_len}")
        if self.blocks_for(need) > self.n_blocks:
            raise ValueError(
                f"request needs {self.blocks_for(need)} blocks but the pool "
                f"holds {self.n_blocks}")
        if self.blocks_for(len(req.prompt)) + self.watermark_blocks \
                > self.n_blocks:
            raise ValueError(
                f"prompt needs {self.blocks_for(len(req.prompt))} blocks "
                f"which can never clear the {self.watermark_blocks}-block "
                f"admission watermark on a {self.n_blocks}-block pool")

    def can_admit(self, n_tokens: int) -> bool:
        """Watermark admission: prompt blocks fit AND the high-watermark
        reserve stays free for decode growth of already-admitted tenants."""
        return (bool(self._free_slots)
                and (self.free_blocks - self.blocks_for(n_tokens)
                     >= self.watermark_blocks))

    def alloc_for(self, req) -> Optional[int]:
        """Admit ``req``: claim a slot + its prompt's blocks; None if the
        watermark would be violated (the scheduler keeps it queued)."""
        n = len(req.prompt)
        if not self.can_admit(n):
            return None
        slot = self._free_slots.popleft()
        self._in_use.add(slot)
        for j in range(self.blocks_for(n)):
            self.tables[slot, j] = self._free_blocks.popleft()
        self._lengths[slot] = n
        return slot

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to cover ``n_tokens`` positions (decode append).
        May eat into the watermark reserve; False when the pool is dry."""
        if slot not in self._in_use:
            raise ValueError(f"slot {slot} is not allocated")
        have = int((self.tables[slot] >= 0).sum())
        while have * self.block_size < n_tokens:
            if not self._free_blocks:
                return False
            self.tables[slot, have] = self._free_blocks.popleft()
            have += 1
        self._lengths[slot] = max(self._lengths[slot], n_tokens)
        return True

    def free(self, slot: int) -> None:
        """Release a request's slot and blocks (FIFO recycle, stale table
        entries cleared so re-issued blocks are unreachable)."""
        if slot not in self._in_use:
            raise ValueError(f"slot {slot} is not allocated")
        self._in_use.remove(slot)
        for j in range(self.max_blocks):
            if self.tables[slot, j] >= 0:
                self._free_blocks.append(int(self.tables[slot, j]))
        self.tables[slot] = -1
        self._lengths[slot] = 0
        self._free_slots.append(slot)

    # -- decode-step views ---------------------------------------------------
    def table_rows(self, slots) -> np.ndarray:
        """[len(slots), max_blocks] int32 block tables for a decode batch."""
        return self.tables[np.asarray(slots, np.int64)]

    # -- occupancy / fragmentation -------------------------------------------
    def report(self) -> Dict[str, float]:
        """Occupancy + fragmentation snapshot (CLI summary / tests)."""
        used_blocks = self.n_blocks - self.free_blocks
        allocated = used_blocks * self.block_size
        used_tokens = int(self._lengths.sum())
        return {
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "used_blocks": used_blocks,
            "free_blocks": self.free_blocks,
            "watermark_blocks": self.watermark_blocks,
            "occupancy": used_blocks / self.n_blocks if self.n_blocks else 0.0,
            "used_tokens": used_tokens,
            "allocated_tokens": allocated,
            # internal fragmentation: allocated-but-unused tail positions of
            # each tenant's last block.
            "internal_fragmentation": (1.0 - used_tokens / allocated
                                       if allocated else 0.0),
        }
