"""TP/DP-sharded serving: run the engine under ``repro.dist.axis_rules``.

A ``ServeSharding`` plan bundles everything the engine needs to execute its
jitted ``decode_step`` SPMD-sharded on a device mesh:

  * the mesh (default: ``launch.mesh.make_host_mesh()`` — the 8-device host
    platform in CI, real accelerators in production),
  * the production logical-axis rules table (with the dry-run's small-KV-head
    retarget: ``kv_seq -> "model"`` when the KV head count does not divide
    the model axis),
  * NamedShardings for params (``param_pspecs``), the pooled decode cache
    (``launch.dryrun.cache_pspecs`` — the same specs the multi-pod dry-run
    lowers against), and — per compacted decode width — the bucketed
    token/pos/table shardings (``bucket_shardings``).

The engine enters ``plan.rules()`` around tracing so every ``shard``/
``shard_spec``/``attention_scheme`` constraint inside the model is live; the
jitted decode horizon steps through the same per-step fn the dry-run
lowers, now actually executing over the mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch.mesh import axis_sizes, make_host_mesh
from repro.models.api import cache_specs, paged_cache_specs, params_specs


@dataclass
class ServeSharding:
    """Mesh + rules table + NamedShardings for one (cfg, n_slots, max_len)."""
    mesh: object
    table: dict
    param_sharding: object
    cache_sharding: object
    cache_pspec: object = field(default=None, repr=False)

    def rules(self):
        """Context manager installing the logical-axis rules for tracing."""
        return shd.axis_rules(self.mesh, self.table)

    def axis_size(self, name: str) -> int:
        """Size of one mesh axis (1 when the mesh does not carry it)."""
        return dict(zip(self.mesh.axis_names,
                        self.mesh.devices.shape)).get(name, 1)

    @property
    def n_devices(self) -> int:
        """Total devices under the plan — the per-chip divisor the
        dispatch profiler's roofline terms use."""
        return int(self.mesh.devices.size)

    def replicated(self) -> NamedSharding:
        """Fully-replicated NamedSharding (the decode-state arrays: they are
        a few int32 per slot — delta-updated from the host — so replication
        beats scattering them)."""
        return NamedSharding(self.mesh, P())

    def bucket_shardings(self, width: int) -> dict:
        """NamedShardings for one compacted decode width: the gathered
        per-row tokens/pos/tables of a width-``width`` bucket shard over the
        mesh 'data' axis when the width divides it (bucket widths are
        rounded to multiples of 'data' for exactly this; only the capped
        full-width bucket of a non-divisible pool falls back to
        replicated). An elastic mesh re-bucket (serve/elastic.py) exploits
        the same fallback: when a ``device_fail`` collapses the engine's
        bucketing multiple, widths stop dividing 'data' and land here as
        replicated layouts — degraded but exact — until a ``device_join``
        restores the multiple."""
        ax = "data" if width % self.axis_size("data") == 0 else None
        return {
            "tokens": NamedSharding(self.mesh, P(ax, None)),
            "pos": NamedSharding(self.mesh, P(ax)),
            "tables": NamedSharding(self.mesh, P(ax, None)),
        }

    def reshard_cache(self, buffers):
        """Re-place a cache pytree under the plan's cache sharding — the
        migration primitive every reshape path shares: after an elastic
        ``grow_physical`` (the reallocated buffers land on whatever
        devices the scatter left them on), and after eager host-side pool
        writes that lose the NamedSharding layout. One gather/scatter per
        leaf, driven by ``cache_sharding``'s partition spec."""
        return jax.device_put(buffers, self.cache_sharding)


def make_serve_sharding(cfg, n_slots: int, max_len: int, mesh=None, *,
                        cache: str = "contiguous", block_size: int = 16,
                        n_blocks=None) -> ServeSharding:
    """Build the sharding plan for a pooled serve engine.

    The cache specs come from ``launch.dryrun.cache_pspecs`` so serve and
    dry-run agree on the decode-cache layout; the batch (slot) dimension
    shards over 'data' when ``n_slots`` divides it, model-parallel axes per
    family as in DESIGN.md §7. With ``cache="paged"`` the specs describe the
    block-pool layout instead (block dimension unsharded, KV heads over
    'model' — see ``cache_pspecs(paged=True)``), so the paged decode step
    lowers sharded exactly like the contiguous one.
    """
    # jax is imported above, so repro.launch.dryrun's XLA_FLAGS preamble
    # (which must only run before first jax init) is a guaranteed no-op here.
    from repro.launch.dryrun import cache_pspecs

    mesh = mesh if mesh is not None else make_host_mesh()
    sizes = axis_sizes(mesh)
    table = shd.production_rules_table("pod" in mesh.axis_names)
    if cfg.n_kv_heads and cfg.n_kv_heads % sizes["model"] != 0:
        table["kv_seq"] = "model"

    with shd.axis_rules(mesh, table) as rules:
        pshape = params_specs(cfg)
        pspec = shd.param_pspecs(pshape, rules)

    if cache == "paged":
        if n_blocks is None:
            n_blocks = n_slots * (-(-max_len // block_size))
        cshape = paged_cache_specs(cfg, n_blocks, block_size)
        cspec = cache_pspecs(cfg, cshape, mesh, seq_shard=False,
                             batch=n_slots, paged=True)
    else:
        cshape = cache_specs(cfg, n_slots, max_len)
        cspec = cache_pspecs(cfg, cshape, mesh, seq_shard=False,
                             batch=n_slots)

    return ServeSharding(
        mesh=mesh,
        table=table,
        param_sharding=shd.named(pspec, mesh),
        cache_sharding=shd.named(cspec, mesh),
        cache_pspec=cspec,
    )


def sharded_engine(cfg, *, n_slots: int = 8, max_len: int = 256,
                   policy: str = "fcfs", params=None, rng=None, mesh=None,
                   cache: str = "contiguous", block_size: int = 16,
                   n_blocks=None, **engine_kw):
    """Convenience constructor: a continuous-batching engine whose decode
    step executes TP/DP-sharded over ``mesh`` (default: the host mesh)."""
    from repro.serve.engine import ServeEngine

    plan = make_serve_sharding(cfg, n_slots, max_len, mesh=mesh, cache=cache,
                               block_size=block_size, n_blocks=n_blocks)
    return ServeEngine(cfg, params=params, max_len=max_len, rng=rng,
                       n_slots=n_slots, policy=policy, sharding=plan,
                       cache=cache, block_size=block_size, n_blocks=n_blocks,
                       **engine_kw)
