"""Open-loop trace replay: Philly-derived arrivals through the serve engine.

The replay half of ROADMAP item 5: ``core.trace`` generates Synergy's §5.1
workload — Philly GPU-demand mix, heavy-tailed 10^x-minute durations,
Poisson arrivals — and this module maps those *training jobs* onto
*serving requests* deterministically, so the serve engine faces the same
arrival process the paper's scheduler does:

  * **arrival step**: the job's Poisson arrival, generated at
    ``jobs_per_hour = 3600 * load`` so one trace-second equals one decode
    step and the mean arrival rate is ``load`` requests/step (open loop:
    arrivals do not wait for completions).
  * **prompt length**: scaled by the job's GPU demand (bigger jobs carry
    bigger prompts) — demand g in {1..16} maps to [prompt_len/2,
    prompt_len] via log2(g)/4.
  * **generation budget**: scaled by the job's duration decade — the
    10^1.5..10^4-minute range maps onto [1, max_new].

Everything is a pure function of ``seed``, which is what lets a chaos
replay (``serve.chaos.FaultInjector``) assert determinism: the same
(workload seed, fault schedule) pair produces the same event trace twice.

``run_replay`` drives a prebuilt engine over the request set and — with
``verify=True`` — re-runs every NON-dropped request (including any the
injector burst in) on the fault-free reference: a single-device static
contiguous engine at ``decode_horizon=1``. Token identity against that
reference is the exactness invariant under chaos; dropped requests are
exempt (they produced no output) but are reported separately.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core import trace as core_trace
from repro.serve.scheduler import ServeRequest


def philly_requests(vocab_size: int, n: int, load: float = 2.0,
                    seed: int = 7, prompt_len: int = 12, max_new: int = 8,
                    max_len: int = 64,
                    tenant_of=None) -> List[ServeRequest]:
    """Deterministic Philly-derived request set (see module docstring).

    ``tenant_of`` optionally maps a ``core.job.Job`` to a tenant id (e.g.
    multi-GPU jobs to the batch tenant); default leaves every request on
    the "default" tenant."""
    if load <= 0:
        raise ValueError("load must be > 0 requests/step")
    jobs = core_trace.philly_trace(n_jobs=n, seed=seed,
                                   jobs_per_hour=3600.0 * load)
    rng = np.random.default_rng(seed)
    cap = max(1, min(prompt_len, max_len - max_new))
    reqs: List[ServeRequest] = []
    for job in jobs:
        # GPU demand (1..16, Philly mix) -> prompt scale in [0.5, 1.0]
        scale = 0.5 + 0.5 * math.log2(max(job.gpu_demand, 1)) / 4.0
        p = max(1, min(cap, int(round(cap * scale))))
        # duration decade (10^1.5 .. 10^4 minutes) -> budget in [1, max_new]
        decade = math.log10(max(job.duration / 60.0, 1.0))
        m = max(1, min(max_new,
                       int(round(max_new * (decade - 1.5) / 2.5))))
        toks = rng.integers(1, max(2, vocab_size), size=p).astype(np.int32)
        reqs.append(ServeRequest(
            prompt=toks, max_new_tokens=m,
            arrival_time=float(job.arrival_time),
            tenant=tenant_of(job) if tenant_of is not None else "default"))
    return reqs


@dataclass
class ReplayResult:
    """One replay's outcome: the served requests (burst arrivals included),
    the run stats, the injected-fault log, and — when asked for — the
    verdict of the fault-free reference check."""
    requests: List[ServeRequest]
    stats: object
    faults: List[tuple] = field(default_factory=list)
    verified: Optional[bool] = None
    mismatched: List[int] = field(default_factory=list)
    dropped: List[int] = field(default_factory=list)


def run_replay(engine, requests: List[ServeRequest], *,
               verify: bool = False, ref_cfg=None,
               ref_max_len: Optional[int] = None) -> ReplayResult:
    """Drive ``engine`` over ``requests``; optionally verify against the
    fault-free K=1 single-device reference.

    ``ref_cfg`` is the ORIGINAL arch config (pre paged-rewrite) the
    reference engine is built from; required when ``verify=True``. The
    reference serves every non-dropped request — originals and injected
    bursts alike — statically (a slot per request, all arrivals at 0), so
    the check isolates token content from scheduling order."""
    out, stats = engine.run(requests)
    res = ReplayResult(
        requests=out, stats=stats,
        faults=(list(engine.injector.injected)
                if getattr(engine, "injector", None) is not None else []),
        dropped=[r.job_id for r in out if r.dropped])
    if not verify:
        return res
    if ref_cfg is None:
        raise ValueError("verify=True needs ref_cfg (the unmodified arch "
                         "config for the reference engine)")
    from repro.serve.engine import ServeEngine
    scored = [r for r in out if not r.dropped]
    ref_engine = ServeEngine(ref_cfg,
                             max_len=ref_max_len or engine.max_len,
                             decode_horizon=1, eos_token=engine.eos_token)
    refs = [ServeRequest(np.asarray(r.prompt).copy(),
                         max_new_tokens=r.max_new_tokens) for r in scored]
    refs, _ = ref_engine.run(refs)
    res.mismatched = [r.job_id for r, ref in zip(scored, refs)
                      if r.output != ref.output]
    res.verified = not res.mismatched
    return res
