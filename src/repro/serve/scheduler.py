"""Continuous-batching scheduler: a request queue with admission by slot
availability and per-step join/evict of finished requests.

Ordering reuses the ``core.policies`` abstractions (a policy only ORDERS the
queue — the same separation Synergy draws for training jobs): FCFS maps onto
``policies.FIFO`` (arrival order) and SJF onto ``policies.SRTF`` (least
remaining work = prompt + generation budget still owed). ``ServeRequest``
exposes the ``arrival_time`` / ``remaining`` / ``job_id`` attributes those
policies sort by.

The clock is the engine's decode-step counter: open-loop arrival processes
set ``arrival_time`` in steps and a request becomes admissible once the
engine clock passes it. Static batching is the degenerate configuration —
every request arrives at step 0 and the pool has one slot per request, so the
first admission round admits everything and no join/evict ever happens
mid-flight.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.policies import FIFO, SRTF, Policy
from repro.obs import NULL_TRACER
from repro.serve.cache import CachePool

#: serve-queue ordering policies (names per the serving literature).
#: "slo" (SLO-slack ordering) is constructed by the engine — it needs a
#: ``tenant.TenantRegistry`` — and arrives here as a Policy instance.
SERVE_POLICIES = {"fcfs": FIFO, "sjf": SRTF}


@dataclass(eq=False)                   # identity equality: prompts are arrays
class ServeRequest:
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 16
    job_id: int = 0
    arrival_time: float = 0.0          # engine decode-step clock
    #: tenant tag — resolved against the engine's ``TenantRegistry`` for
    #: SLO slack, per-tenant budgets, and per-tenant stats (see
    #: serve/tenant.py). Untagged requests share the "default" tenant.
    tenant: str = "default"
    output: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    #: set when the engine stops the request before its budget (EOS token):
    #: ``done`` then holds even though fewer than max_new_tokens were emitted.
    finished_early: bool = False
    #: times this request was preempted under pool pressure (each bounce
    #: regenerates its tokens identically after re-admission)
    n_preempted: int = 0
    # -- fault recovery (serve/chaos.py; all idle without an injector) ------
    #: admission retries burned after a pool_shrink left the request
    #: unservable (bounded retry-with-backoff), and the step the next
    #: retry is due at
    n_retries: int = 0
    next_retry: float = 0.0
    #: set when a fault-recovery path gave up on the request: dropped
    #: requests are excluded from slo_attainment's denominator and counted
    #: separately from ``unfinished`` (see ServeStats)
    dropped: bool = False
    drop_cause: Optional[str] = None
    # wall clocks: t_arrived is stamped when the engine clock first passes
    # arrival_time (NOT at admission), so latency_s includes queue wait.
    t_arrived: Optional[float] = None
    t_admitted: Optional[float] = None
    t_finished: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finished_early or len(self.output) >= self.max_new_tokens

    @property
    def remaining(self) -> float:
        """Work still owed (SJF key): prompt prefill + tokens left."""
        return float(len(self.prompt) + self.max_new_tokens - len(self.output))

    @property
    def latency_steps(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.arrival_time

    @property
    def latency_s(self) -> Optional[float]:
        """Wall seconds from becoming admissible to finishing (incl. queue)."""
        if self.t_finished is None or self.t_arrived is None:
            return None
        return self.t_finished - self.t_arrived


class ContinuousScheduler:
    """Admission + eviction over a ``CachePool``, ordered by a queue policy.

    ``policy`` is a registered name or a ``core.policies.Policy`` instance
    (the engine passes ``tenant.SLOSlack`` for SLO-slack ordering).
    ``allocation`` (a ``tenant.TenantAllocation``) adds a per-tenant
    cache-unit budget check at admission: a request over its tenant's
    budget is skipped — NOT queued-blocking, so other tenants' admissible
    requests behind it still admit this round.

    ``tracer`` (an ``obs.Tracer``) records every admission decision —
    admit / budget_skip / defer / preempt — as structured events; the
    default ``NULL_TRACER`` is falsy, so tracing off costs one branch per
    decision.
    """

    def __init__(self, pool: CachePool, policy="fcfs", allocation=None,
                 tracer=NULL_TRACER):
        if isinstance(policy, Policy):
            self.policy: Policy = policy
        elif policy in SERVE_POLICIES:
            self.policy = SERVE_POLICIES[policy]()
        else:
            raise KeyError(f"unknown serve policy {policy!r}; "
                           f"known: {sorted(SERVE_POLICIES)}")
        self.pool = pool
        self.allocation = allocation
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.n_preempted = 0           # cumulative preemptions this run
        self.waiting: List[ServeRequest] = []
        self.active: Dict[int, ServeRequest] = {}
        #: admitted-but-not-yet-prefilled requests: the engine drains this
        #: queue into its prefill lanes, so joins admitted in one round are
        #: co-scheduled into shared chunk-round dispatches.
        self.prefill_queue: deque = deque()
        self.step: int = 0

    def submit(self, req: ServeRequest) -> None:
        if hasattr(self.pool, "validate_request"):
            self.pool.validate_request(req)      # paged: blocks + table span
        elif len(req.prompt) + req.max_new_tokens > self.pool.max_len:
            raise ValueError(
                f"request needs {len(req.prompt) + req.max_new_tokens} cache "
                f"positions but the pool holds {self.pool.max_len}")
        self.waiting.append(req)

    def park(self, req: ServeRequest) -> None:
        """Queue a request the CURRENT pool cannot validate but scheduled
        capacity — a pending restore/join fault, or proactive scale-up
        headroom — will later cover: it waits for the engine's bounded
        retry admission instead of being rejected at submit. Safe because
        ``admit`` re-checks capacity every round (``alloc_for`` simply
        fails while the pool is still small), so a parked request can
        never corrupt the pool — only wait for it."""
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    def next_arrival(self) -> Optional[float]:
        return min((r.arrival_time for r in self.waiting), default=None)

    def admit(self, hold=None) -> List[ServeRequest]:
        """Admit policy-ordered admissible requests while slots are free.

        ``hold`` (chaos.FaultInjector admission stalls) maps a request to
        a defer cause or None: a held request skips this round — emitted
        as a ``defer`` event — without blocking the requests behind it.
        """
        ready = [r for r in self.waiting if r.arrival_time <= self.step]
        now = time.perf_counter()
        for r in ready:
            if r.t_arrived is None:
                r.t_arrived = now
        admitted = []
        tr = self.tracer
        for req in self.policy.order(ready, float(self.step)):
            if hold is not None:
                cause = hold(req)
                if cause is not None:
                    if tr:
                        tr.emit("defer", req=req.job_id, tenant=req.tenant,
                                cause=cause)
                    continue
            # tenant budget: a request past its tenant's cache-unit budget
            # is skipped (its tenant already holds its allocated share) —
            # other tenants' requests behind it still admit this round.
            if (self.allocation is not None
                    and not self.allocation.admissible(req, self.active,
                                                       self.pool)):
                if tr:
                    why = self.allocation.last_decision or {}
                    tr.emit("budget_skip", req=req.job_id, tenant=req.tenant,
                            held=why.get("held"), need=why.get("need"),
                            budget=why.get("budget"))
                continue
            # paged pools admit by free *blocks* (length-proportional, with a
            # watermark reserve); slot pools by free slots.
            slot = (self.pool.alloc_for(req)
                    if hasattr(self.pool, "alloc_for") else self.pool.alloc())
            if slot is None:
                # a prefix-cache deferral (donor still prefilling) parks only
                # THAT request — unrelated admissible requests behind it must
                # not wait a round; pool exhaustion still ends the scan.
                if getattr(self.pool, "deferred_last_alloc", False):
                    if tr:
                        tr.emit("defer", req=req.job_id, tenant=req.tenant,
                                cause="prefix_unready")
                    continue
                break
            req.slot = slot
            req.admitted_at = float(self.step)
            req.t_admitted = time.perf_counter()
            self.active[slot] = req
            self.waiting.remove(req)
            self.prefill_queue.append(req)
            admitted.append(req)
            if tr:
                units = (self.pool.owned_blocks(slot)
                         if hasattr(self.pool, "owned_blocks") else 1)
                tr.emit("admit", req=req.job_id, tenant=req.tenant, slot=slot,
                        prompt_len=len(req.prompt),
                        max_new=req.max_new_tokens,
                        wait_steps=float(self.step) - req.arrival_time,
                        units=units)
        return admitted

    def drain_prefill(self) -> List[ServeRequest]:
        """All admitted requests awaiting prefill (clears the queue)."""
        items = list(self.prefill_queue)
        self.prefill_queue.clear()
        return items

    def preempt(self, req: ServeRequest, cause: str = "pool_pressure") -> None:
        """Return an active request to the queue under block-pool pressure.

        Its slot and blocks are freed and its generated tokens discarded;
        after re-admission the deterministic prefill + greedy decode
        regenerate them identically, so preemption is invisible in outputs.
        """
        if req.slot is None or self.active.get(req.slot) is not req:
            raise ValueError("can only preempt an active request")
        self.n_preempted += 1
        if self.tracer:
            self.tracer.emit("preempt", req=req.job_id, tenant=req.tenant,
                             slot=req.slot, cause=cause,
                             n_preempted=self.n_preempted)
        self.pool.free(req.slot)
        del self.active[req.slot]
        req.slot = None
        req.admitted_at = None
        req.t_admitted = None
        req.output = []
        req.finished_early = False
        req.n_preempted += 1
        self.waiting.append(req)

    def evict_finished(self) -> List[ServeRequest]:
        """Release slots of finished requests (the per-step evict half)."""
        done = [r for r in self.active.values() if r.done]
        for req in done:
            # the engine may have pre-stamped the exact finishing step (a
            # multi-step decode horizon evicts only at horizon boundaries);
            # only fill in the boundary step when it has not.
            if req.finished_at is None:
                req.finished_at = float(self.step)
            req.t_finished = time.perf_counter()
            self.pool.free(req.slot)
            del self.active[req.slot]
            req.slot = None
        return done
