"""Pooled decode-cache with per-slot alloc/free.

One padded cache buffer (the model's ``init_cache(n_slots, max_len)`` pytree)
is shared by all in-flight requests; each request owns one *slot* — one index
along the batch dimension of every leaf. Requests of different lengths
coexist because each slot keeps its own write position (threaded through the
per-row ``pos`` vector of ``decode_step``) and the decode mask only spans
``[0, pos]`` per row.

The batch axis is not the same dimension in every leaf (transformer KV stacks
are ``[L, B, S, kv, hd]`` — axis 1 — while zamba2's grouped mamba states are
``[G, E, B, ...]`` — axis 2), so the pool infers each leaf's batch axis once
at construction by diffing the shapes of two ``eval_shape`` probes with
different batch sizes. ``write`` replaces an entire slot row, so a recycled
slot never sees its previous tenant's state.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp


def _batch_axis(shape_a, shape_b) -> int:
    """Index of the (single) differing dimension between two probe shapes."""
    diff = [i for i, (a, b) in enumerate(zip(shape_a.shape, shape_b.shape))
            if a != b]
    if len(diff) != 1:
        raise ValueError(
            f"cannot locate batch axis: {shape_a.shape} vs {shape_b.shape}")
    return diff[0]


class CachePool:
    """Slot-managed decode cache over a model's ``init_cache`` pytree.

    Slots are recycled FIFO: freed slots go to the back of the free queue, so
    a request never lands in the most-recently-vacated row while its previous
    tenant's final decode step may still be in flight.
    """

    def __init__(self, model, n_slots: int, max_len: int):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        probe_a = jax.eval_shape(lambda: model.init_cache(3, max_len))
        probe_b = jax.eval_shape(lambda: model.init_cache(5, max_len))
        self.batch_axes = jax.tree_util.tree_map(_batch_axis, probe_a, probe_b)
        self.buffers = model.init_cache(n_slots, max_len)
        self._free = deque(range(n_slots))
        self._in_use: set = set()
        #: elastic capacity (serve/elastic.py): slots revoked by a
        #: device_fail / scale_down — physically still in the buffers (the
        #: arrays never reallocate) but withheld from allocation until a
        #: device_join / scale_up expands them back.
        self._revoked: list = []

    # -- slot management -----------------------------------------------------
    def alloc(self) -> Optional[int]:
        """Claim a slot; None when the pool is full."""
        if not self._free:
            return None
        slot = self._free.popleft()
        self._in_use.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._in_use:
            raise ValueError(f"slot {slot} is not allocated")
        self._in_use.remove(slot)
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self):
        return frozenset(self._in_use)

    @property
    def capacity(self) -> int:
        """Live slot capacity: total minus elastically revoked slots (the
        contiguous twin of ``BlockManager.n_blocks``)."""
        return self.n_slots - len(self._revoked)

    @property
    def utilization(self) -> float:
        return len(self._in_use) / max(self.capacity, 1)

    # -- elastic capacity (serve/elastic.py reshape surface) -----------------
    def shrink(self, n: int) -> int:
        """Revoke up to ``n`` IDLE slots of capacity (a ``device_fail`` /
        ``scale_down`` on the contiguous backend). Only idle slots are
        revocable — in-flight rows keep their device state — and at least
        one slot of capacity always survives. Returns the slots revoked."""
        take = max(0, min(int(n), len(self._free), self.capacity - 1))
        for _ in range(take):
            self._revoked.append(self._free.pop())
        return take

    def expand(self, n: int) -> int:
        """Return up to ``n`` revoked slots (``device_join`` / ``scale_up``)
        to the free list. Returns the slots restored."""
        give = min(int(n), len(self._revoked))
        for _ in range(give):
            self._free.append(self._revoked.pop())
        return give

    # -- buffer access ---------------------------------------------------------
    def write(self, slot: int, row_cache) -> None:
        """Install a batch-1 cache pytree (same ``max_len``) into ``slot``.

        Replaces the entire slot row of every leaf, so stale state from a
        previous occupant can never leak into the new request's decode. A row
        whose non-batch dimensions disagree with the pool (a ``max_len``
        mismatch, most commonly) is rejected — silently broadcasting a short
        row across a longer slot would corrupt the decode mask's invariants.
        """
        if slot not in self._in_use:
            raise ValueError(f"slot {slot} is not allocated")

        def put(buf, row, ax):
            row = jnp.asarray(row)
            expect = buf.shape[:ax] + (1,) + buf.shape[ax + 1:]
            if row.shape != expect:
                raise ValueError(
                    f"row cache leaf shape {row.shape} does not match the "
                    f"pool's slot shape {expect} (max_len mismatch?)")
            if jnp.dtype(row.dtype) != jnp.dtype(buf.dtype):
                raise ValueError(
                    f"row cache dtype {row.dtype} does not match the pool's "
                    f"{buf.dtype}")
            sel = (slice(None),) * ax
            return buf.at[sel + (slot,)].set(row[sel + (0,)])

        self.buffers = jax.tree_util.tree_map(put, self.buffers, row_cache,
                                              self.batch_axes)

    def read_slot(self, slot: int):
        """The slot's cache row as a batch-1 pytree (tests / debugging)."""
        def take(buf, ax):
            sel = (slice(None),) * ax
            return buf[sel + (slice(slot, slot + 1),)]

        return jax.tree_util.tree_map(take, self.buffers, self.batch_axes)
