"""Elastic serving: live pool/mesh reshaping at horizon boundaries.

ROADMAP item 4 (the DLRover ScalePlan idiom) applied to this engine: the
capacity a serve run sees is not static — devices fail and rejoin, pools
shrink under co-tenant pressure and grow back — and Synergy's continuous
re-packing argument applies to the *serving* pool exactly as it does to the
training cluster. This module owns the *decision* side of elasticity; the
engine owns application (it holds the scheduler, pool, device state and
sharding) and performs every reshape at a horizon boundary, where device
state is already host-synced and delta-scattered.

A reshape is described by a ``ScalePlan`` — grow or shrink, how many cache
units, why, and (optionally) the new mesh 'data' bucketing multiple — and
plans come from two sources:

  * **reactive**: ``device_fail`` / ``device_join`` faults (serve/chaos.py)
    force a plan at the boundary they fire on. A fail revokes blocks AND
    narrows the bucketing multiple (decode buckets stop being data-axis
    multiples, so ``ServeSharding.bucket_shardings`` degrades them to
    replicated layouts — slower, still exact); a join returns capacity —
    growing PAST the original allocation when needed, in which case
    ``BlockManager.grow_physical`` migrates every live KV block into the
    larger buffers — and restores the multiple.
  * **proactive**: ``ElasticController.decide`` reads the run's
    ``obs.MetricsRegistry`` (the occupancy / queue-depth / slack gauges the
    engine samples at exactly these boundaries) and emits a plan when a
    threshold is crossed: occupancy or queue depth high → scale up toward
    ``max_units``; pool idle → scale down toward ``min_units``. A cooldown
    keeps the controller from thrashing against its own reshapes (and
    against chaos recovery, which shares the cooldown clock).

Every reshape preserves the exactness invariant: migration moves state, it
never recomputes it, and admission/bucketing changes are reorder-only — so
non-dropped greedy outputs stay token-identical to the fault-free K=1
single-device reference (``--verify`` holds across any reshape sequence).

``ElasticController.pending_units`` is the admission side's window into
proactive capacity: a request that cannot fit the current pool but fits
``capacity + pending`` is *held* with bounded retry instead of dropped —
the same hold-don't-drop contract ``FaultInjector.pending_capacity`` gives
scheduled restores.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


def pool_capacity(pool) -> int:
    """Live cache-unit capacity of either backend: KV blocks for the paged
    ``BlockManager``, live (non-revoked) slots for the contiguous
    ``CachePool``."""
    if hasattr(pool, "n_blocks"):
        return int(pool.n_blocks)
    return int(getattr(pool, "capacity", pool.n_slots))


@dataclass(frozen=True)
class ScalePlan:
    """One reshape decision: direction, magnitude, provenance.

    ``units`` is the capacity delta in cache units (blocks / slots);
    ``dmult`` is the new mesh 'data' bucketing multiple the engine should
    round decode widths to after the reshape (None = unchanged — proactive
    pool-only reshapes never touch the mesh)."""
    kind: str                      # "scale_up" | "scale_down"
    units: int                     # capacity delta, >= 0 (0 = pure mesh
                                   # re-bucket: only ``dmult`` changes)
    reason: str                    # "device_fail" | "device_join" |
                                   # "occupancy" | "queue_depth" | "slack"
    step: float = 0.0              # boundary the decision was made at
    dmult: Optional[int] = None    # new data-axis multiple (None = keep)

    def __post_init__(self):
        if self.kind not in ("scale_up", "scale_down"):
            raise ValueError(f"unknown scale kind {self.kind!r}")
        if self.units < 0:
            raise ValueError("a ScalePlan cannot move negative units")
        if self.units == 0 and self.dmult is None:
            raise ValueError("a ScalePlan must move units or change dmult")


class ElasticController:
    """Threshold-driven proactive scale decisions over the metrics gauges.

    ``decide`` is called once per horizon boundary with the engine's pool
    and live ``MetricsRegistry``; it returns a ``ScalePlan`` or None. The
    thresholds read the gauges the engine already samples there:

      * ``occupancy >= occupancy_hi`` or ``queue_depth >= queue_hi`` or any
        ``slack[tenant] <= slack_lo`` → scale UP by ``step_units`` (capped
        at ``max_units`` total capacity),
      * ``occupancy <= occupancy_lo`` and the queue empty → scale DOWN by
        ``step_units`` (floored at ``min_units``).

    ``max_units`` defaults to the pool's capacity at first sight (proactive
    growth then only *reclaims* revoked capacity); ``min_units`` defaults
    the same way (no proactive shrink unless configured below it). The
    controller is deliberately clock-free: ``cooldown`` is measured on the
    engine's decode-step clock, so decisions replay deterministically.
    """

    def __init__(self, *, occupancy_hi: float = 0.92,
                 occupancy_lo: float = 0.15, queue_hi: int = 6,
                 slack_lo: float = 0.0, step_units: int = 8,
                 max_units: Optional[int] = None,
                 min_units: Optional[int] = None, cooldown: float = 16.0):
        if not 0.0 <= occupancy_lo < occupancy_hi <= 1.0:
            raise ValueError("need 0 <= occupancy_lo < occupancy_hi <= 1")
        if step_units < 1:
            raise ValueError("step_units must be >= 1")
        self.occupancy_hi = float(occupancy_hi)
        self.occupancy_lo = float(occupancy_lo)
        self.queue_hi = int(queue_hi)
        self.slack_lo = float(slack_lo)
        self.step_units = int(step_units)
        self.max_units = max_units if max_units is None else int(max_units)
        self.min_units = min_units if min_units is None else int(min_units)
        self.cooldown = float(cooldown)
        self.reset()

    def reset(self) -> None:
        """Re-arm for a fresh run (the engine calls this from ``run`` so
        warm-up double-runs replay identical decisions)."""
        self._last_scale = -float("inf")
        self.decisions: list = []      # applied (kind, reason, step) log

    # -- the cooldown clock (shared with reactive reshapes) ------------------
    def note_scale(self, step: float, plan: ScalePlan) -> None:
        """Record an APPLIED reshape (reactive or proactive) — both arms
        share one cooldown so the controller never fights chaos recovery."""
        self._last_scale = float(step)
        self.decisions.append((plan.kind, plan.reason, float(step)))

    def _bind_limits(self, capacity: int) -> None:
        if self.max_units is None:
            self.max_units = int(capacity)
        if self.min_units is None:
            self.min_units = int(capacity)

    def pending_units(self, pool) -> int:
        """Capacity a proactive scale-up could still add — the admission
        path counts this (plus the injector's scheduled restores) before
        giving up on a request that does not fit the current pool."""
        self._bind_limits(pool_capacity(pool))
        return max(0, self.max_units - pool_capacity(pool))

    def decide(self, step: float, pool, metrics) -> Optional[ScalePlan]:
        """One boundary's proactive decision (None = leave the pool alone).

        ``metrics`` is the run's ``MetricsRegistry``; the occupancy /
        queue_depth / slack[...] gauges were set this boundary, so the
        decision reads the engine's *current* state, not a stale sample.
        """
        capacity = pool_capacity(pool)
        self._bind_limits(capacity)
        if step - self._last_scale < self.cooldown:
            return None
        if "occupancy" not in metrics.gauges:
            return None                # no boundary sampled yet: the run
                                       # has not started decoding
        occ = metrics.value("occupancy")
        queue = metrics.value("queue_depth")
        slacks = [g.value for name, g in metrics.gauges.items()
                  if name.startswith("slack[")]

        reason = None
        if occ >= self.occupancy_hi:
            reason = "occupancy"
        elif queue >= self.queue_hi:
            reason = "queue_depth"
        elif slacks and min(slacks) <= self.slack_lo:
            reason = "slack"
        if reason is not None:
            grow = min(self.step_units, self.max_units - capacity)
            if grow > 0:
                return ScalePlan(kind="scale_up", units=grow, reason=reason,
                                 step=float(step))
            return None

        if occ <= self.occupancy_lo and queue <= 0:
            shrink = min(self.step_units, capacity - self.min_units)
            # never shrink below what the live requests are holding
            held = capacity - getattr(pool, "free_blocks", 0) \
                if hasattr(pool, "free_blocks") else 0
            shrink = min(shrink, capacity - max(self.min_units, held))
            if shrink > 0:
                return ScalePlan(kind="scale_down", units=shrink,
                                 reason="occupancy", step=float(step))
        return None
