"""Open-loop trace replay with deterministic fault injection.

Feeds a Philly-derived arrival process (``repro.serve.replay``) through
the serve engine at a configurable load while a seeded
``FaultInjector`` (``repro.serve.chaos``) applies a declarative fault
schedule keyed to the engine's decode-step clock. Records PR 7 event
traces, and with ``--verify`` asserts the exactness invariant: every
non-dropped request's greedy output is token-identical to the
fault-free K=1 single-device static reference.

Example — 3-fault chaos smoke on the host mesh::

    PYTHONPATH=src python -m repro.launch.replay \\
        --arch qwen2-0.5b --cache paged --mesh host --slots 8 \\
        --n 16 --load 2.0 --max-len 64 --prompt-len 12 --max-new 8 \\
        --faults "slot_kill@8,prefix_flush@12,pool_shrink@16:blocks=6" \\
        --trace /tmp/replay_trace.jsonl --verify

Fault specs are ``kind@step[:key=val...]`` (comma-separated) or a JSON
schedule file via ``--faults-file`` (see ``FaultSchedule.to_json``).
"""
import os
import sys

from repro.launch._bootstrap import force_host_devices, mesh_flag

if mesh_flag(sys.argv) == "host":
    force_host_devices(os.environ.get("REPRO_SERVE_DEVICES", "8"))

import jax  # noqa: E402  (lock the device count before any repro import)

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402

from repro.configs import ARCH_IDS, get_config                    # noqa: E402
from repro.serve import (FaultInjector, FaultSchedule,            # noqa: E402
                         ServeEngine, philly_requests, run_replay,
                         sharded_engine)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--cache", default="paged",
                    choices=["contiguous", "paged"])
    ap.add_argument("--mesh", default="single", choices=["single", "host"])
    ap.add_argument("--policy", default="fcfs",
                    choices=["fcfs", "sjf", "slo"])
    ap.add_argument("--n", type=int, default=16,
                    help="number of Philly-derived requests in the replay")
    ap.add_argument("--load", type=float, default=2.0,
                    help="mean open-loop arrival rate in requests per "
                         "decode step (Poisson)")
    ap.add_argument("--seed", type=int, default=7,
                    help="workload seed: arrivals, prompt contents, budgets")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="fault-schedule seed: victim picks, burst contents")
    ap.add_argument("--faults", default="",
                    help="comma-separated fault specs, each "
                         "'kind@step[:key=val...]', e.g. "
                         "'slot_kill@8,pool_shrink@16:blocks=6'")
    ap.add_argument("--faults-file", default=None, metavar="PATH",
                    help="JSON fault schedule (overrides --faults)")
    ap.add_argument("--slots", type=int, default=4,
                    help="cache-pool slots (continuous engine)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV positions per block (paged cache)")
    ap.add_argument("--blocks", type=int, default=0,
                    help="paged pool size in blocks "
                         "(0 = slots * ceil(max_len / block_size))")
    ap.add_argument("--watermark", type=float, default=0.05,
                    help="fraction of blocks reserved at admission (paged)")
    ap.add_argument("--prefill-lanes", type=int, default=4,
                    help="joining requests prefilled per jitted chunk-round "
                         "(paged cache)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="disable content-hashed prompt-block sharing (paged)")
    ap.add_argument("--prompt-len", type=int, default=12,
                    help="max prompt length (GPU demand scales in [len/2, "
                         "len])")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--decode-horizon", type=int, default=8,
                    help="decode steps per jitted dispatch (the injector "
                         "caps this so faults land on their step)")
    ap.add_argument("--eos-token", type=int, default=None,
                    help="stop a request early when it emits this token id")
    ap.add_argument("--max-admit-retries", type=int, default=4,
                    help="admission retries with exponential backoff before "
                         "a request is dropped during pool_shrink")
    ap.add_argument("--elastic", action="store_true",
                    help="install an ElasticController: proactive scale "
                         "up/down from the occupancy/queue/slack gauges, "
                         "on top of reactive device_fail/device_join "
                         "recovery")
    ap.add_argument("--elastic-max-units", type=int, default=None,
                    help="proactive scale-up capacity ceiling in cache "
                         "units (default: the pool's constructed size)")
    ap.add_argument("--elastic-min-units", type=int, default=None,
                    help="proactive scale-down floor (default: no "
                         "proactive shrink below the constructed size)")
    ap.add_argument("--elastic-step-units", type=int, default=8,
                    help="cache units moved per proactive reshape")
    ap.add_argument("--elastic-cooldown", type=float, default=16.0,
                    help="decode steps between reshapes (shared between "
                         "proactive decisions and chaos recovery)")
    ap.add_argument("--verify", action="store_true",
                    help="check every non-dropped output against the "
                         "fault-free single-device static engine")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="dump a structured event trace of the replay here "
                         "(analyze with repro.launch.trace_report)")
    ap.add_argument("--trace-format", default="jsonl",
                    choices=["jsonl", "chrome"])
    ap.add_argument("--trace-capacity", type=int, default=1 << 16)
    ap.add_argument("--metrics-every", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.preset == "smoke")

    if args.faults_file:
        schedule = FaultSchedule.from_json(args.faults_file)
    else:
        schedule = FaultSchedule.from_spec(args.faults, seed=args.chaos_seed)
    injector = FaultInjector(schedule, seed=args.chaos_seed)

    reqs = philly_requests(cfg.vocab_size, args.n, load=args.load,
                           seed=args.seed, prompt_len=args.prompt_len,
                           max_new=args.max_new, max_len=args.max_len)

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(capacity=args.trace_capacity)

    elastic = None
    if args.elastic:
        from repro.serve import ElasticController
        elastic = ElasticController(step_units=args.elastic_step_units,
                                    max_units=args.elastic_max_units,
                                    min_units=args.elastic_min_units,
                                    cooldown=args.elastic_cooldown)

    engine_kw = dict(cache=args.cache, block_size=args.block_size,
                     n_blocks=args.blocks or None,
                     watermark=args.watermark,
                     prefill_lanes=args.prefill_lanes,
                     prefix_cache=args.prefix_cache,
                     decode_horizon=args.decode_horizon,
                     eos_token=args.eos_token,
                     injector=injector, elastic=elastic,
                     max_admit_retries=args.max_admit_retries,
                     tracer=tracer, metrics_every=args.metrics_every)

    if args.mesh == "host":
        engine = sharded_engine(cfg, n_slots=args.slots,
                                max_len=args.max_len, policy=args.policy,
                                **engine_kw)
    else:
        engine = ServeEngine(cfg, max_len=args.max_len, n_slots=args.slots,
                             policy=args.policy, **engine_kw)

    res = run_replay(engine, reqs, verify=args.verify, ref_cfg=cfg,
                     ref_max_len=args.max_len)

    trace_info = None
    if tracer is not None:
        if args.trace_format == "chrome":
            from repro.obs import write_chrome_trace
            write_chrome_trace(args.trace, tracer.events)
        else:
            tracer.dump_jsonl(args.trace)
        trace_info = {"path": args.trace, "format": args.trace_format,
                      "events": len(tracer), "dropped": tracer.dropped}

    record = {
        "arch": cfg.arch_id,
        "cache": args.cache,
        "mesh": args.mesh,
        "policy": args.policy,
        "n_devices": jax.device_count(),
        "slots": args.slots,
        "load": args.load,
        "n_requests": len(res.requests),
        "faults": [{"kind": k, "step": s} for k, s in res.faults],
        "dropped_ids": res.dropped,
        "elastic": bool(elastic),
        **dataclasses.asdict(res.stats),
    }
    if trace_info is not None:
        record["trace"] = trace_info
    if args.verify:
        record["verified"] = bool(res.verified)
        record["mismatched"] = res.mismatched
    print(json.dumps(record, indent=2, default=float))

    if args.verify and not res.verified:
        raise SystemExit(
            f"FAIL: {len(res.mismatched)} non-dropped request(s) diverged "
            f"from the fault-free reference: {res.mismatched}")


if __name__ == "__main__":
    main()
