"""Roofline report generator: reads experiments/dryrun.jsonl, emits the
per-(arch x shape) table for EXPERIMENTS.md §Roofline.

    PYTHONPATH=src python -m repro.launch.roofline [--jsonl experiments/dryrun.jsonl]
"""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict


def load(jsonl: str):
    recs = {}
    with open(jsonl) as f:
        for line in f:
            r = json.loads(line)
            key = (r["arch"], r["shape"], r["mesh"], r.get("tag"))
            recs[key] = r           # last write wins (re-runs supersede)
    return recs


def _num(v, spec: str, scale: float = 1.0) -> str:
    """Format a possibly-missing numeric field; ``None`` renders as an em
    dash (multipod records without probes, CPU backends whose
    cost_analysis reports no FLOPs)."""
    return "—" if v is None else f"{v * scale:{spec}}"


def fmt_row(r) -> str:
    c, m, k = r["compute_s"], r["memory_s"], r["collective_s"]
    dom = r["bottleneck"]
    ratio = r.get("useful_flop_ratio")
    mem = r.get("memory_stats") or {}
    peak = mem.get("peak_bytes") or mem.get("bytes_per_device") or 0
    args = r.get("args_gib_per_device", "")
    return (f"| {r['arch']} | {r['shape']} | {c * 1e3:.1f} | {m * 1e3:.1f} | "
            f"{k * 1e3:.1f} | **{dom}** | {_num(ratio, '.2f')} | "
            f"{_num(r.get('flops_per_chip'), '.2f', 1e-12)} | {args} |")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="experiments/dryrun.jsonl")
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    recs = load(args.jsonl)

    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
          "bottleneck | useful-FLOP ratio | TFLOP/chip | args GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    rows = [r for (a, s, m, t), r in sorted(recs.items())
            if m == args.mesh and t is None]
    for r in rows:
        print(fmt_row(r))

    doms = {}
    for r in rows:
        doms[r["bottleneck"]] = doms.get(r["bottleneck"], 0) + 1
    print(f"\n{len(rows)} combos; bottleneck counts: {doms}")

    # multipod pass/fail summary
    mp = [r for (a, s, m, t), r in sorted(recs.items())
          if m == "multipod" and t is None]
    print(f"multipod (2x16x16 = 512 chips) lowered+compiled: {len(mp)} combos")


if __name__ == "__main__":
    main()
