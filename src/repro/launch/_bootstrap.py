"""Pre-jax process bootstrap shared by the launch CLIs.

The host-platform device count is locked at first jax init, so drivers that
want a forced multi-device CPU platform must set XLA_FLAGS before anything
imports jax. This module must therefore stay import-light (os/sys only).
"""
from __future__ import annotations

import os
import sys
from typing import List, Optional


def mesh_flag(argv: List[str]) -> Optional[str]:
    """The value of a ``--mesh X`` / ``--mesh=X`` argument, if present."""
    for i, a in enumerate(argv):
        if a.startswith("--mesh="):
            return a.split("=", 1)[1]
        if a == "--mesh" and i + 1 < len(argv):
            return argv[i + 1]
    return None


def force_host_devices(n) -> None:
    """Force ``n`` host-platform devices before the first jax init.

    No-op when jax is already imported (the count is locked) or when the
    flag is already present (e.g. conftest.py or a sweep env set it). Any
    pre-existing XLA_FLAGS (or the legacy ``_EXTRA_XLA_FLAGS`` base) are
    preserved, not clobbered.
    """
    if "jax" in sys.modules:
        return
    flags = os.environ.get("XLA_FLAGS", "") or os.environ.get(
        "_EXTRA_XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        flags = f"{flags} --xla_force_host_platform_device_count={n}"
    os.environ["XLA_FLAGS"] = flags.strip()
