"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct inputs — no allocation — and extract
the roofline terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh pod --out experiments/dryrun.jsonl

The XLA_FLAGS bootstrap below is the FIRST executable statement — before
any jax import (device count is locked at first init). REPRO_DRYRUN_DEVICES
overrides the forced device count (CI smoke runs use 8 with --mesh host);
when jax is already imported (in-process test usage) the flag is left alone.
"""
import os
import sys

from repro.launch._bootstrap import force_host_devices, mesh_flag

force_host_devices(os.environ.get(
    "REPRO_DRYRUN_DEVICES", "8" if mesh_flag(sys.argv) == "host" else "512"))

import argparse
import json
import re
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.dist import sharding as shd
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               axis_sizes, make_host_mesh,
                               make_production_mesh)
from repro.models.api import build_model, cache_specs, input_specs, params_specs
from repro.train import state as state_lib
from repro.train.optimizer import adamw, constant

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in the (post-SPMD) HLO.

    Approximation documented in EXPERIMENTS.md: bytes-on-the-wire per chip is
    ~(output bytes) for all-reduce (ring: 2(n-1)/n ~ 2x input) and
    ~(gathered bytes x (n-1)/n) for all-gather; we report raw output bytes
    per op kind and fold the ring factors into the roofline term.
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        stripped = line.strip().lstrip("%")
        for kind in _COLLECTIVES:
            # match "= <shape> kind(" including fusion-wrapped ("...-start")
            if re.search(rf"= [^=]*\b{kind}(-start)?\(", stripped):
                eq = stripped.split("=", 1)[1]
                lhs = eq.split(kind, 1)[0]
                for dt, dims in _SHAPE_RE.findall(lhs):
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    if dims:
                        for d in dims.split(","):
                            n *= int(d)
                    out[kind] += n * _DTYPE_BYTES[dt]
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(n: int, size: int) -> bool:
    return n % size == 0 and n > 0


def cache_pspecs(cfg, cache_shape, mesh, *, seq_shard: bool, batch: int,
                 paged: bool = False):
    """PartitionSpecs for the decode cache, per family (DESIGN.md §7).

    KV head counts that do not divide the model axis fall back to sharding
    the cache SEQ dimension over 'model' (whisper kv=20, qwen2-7b kv=4,
    phi3.5-moe kv=8 at 32k x batch 128 do not fit HBM otherwise); decode
    attention handles a seq-sharded KV via partial-softmax all-reduce.

    ``paged=True`` describes the block-pool layout instead: k/v leaves are
    ``[L, n_blocks, block_size, kv, hd]`` — the block dimension stays
    unsharded (any slot's table may name any block, so blocks must be
    addressable without a gather collective), KV heads shard over 'model',
    and the small-KV-head fallback shards the in-block position dimension."""
    ba = _batch_axes(mesh)
    bsz = 1
    for a in ba:
        bsz *= axis_sizes(mesh)[a]
    b_ax = ba if _div(batch, bsz) else None
    msize = axis_sizes(mesh)["model"]

    def spec_for(path, leaf):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        shape = leaf.shape
        def m_ax(dim):
            return "model" if _div(shape[dim], msize) else None
        if paged and name in ("k", "v"):
            # [L, NB, BS, kv, hd]
            s_ax = ("model" if m_ax(3) is None and _div(shape[2], msize)
                    else None)
            return P(None, None, s_ax, m_ax(3), None)
        if name in ("k", "v") or name.endswith(("attn_k", "attn_v")):
            # [L_or_G, B, S, kv, hd]
            if seq_shard:
                s_ax = "data"
            elif m_ax(3) is None and _div(shape[2], msize):
                s_ax = "model"
            else:
                s_ax = None
            return P(None, b_ax, s_ax, m_ax(3), None)
        if name in ("ck", "cv"):
            return P(None, b_ax, None, m_ax(3), None)
        if name.endswith("conv") and leaf.ndim == 4:     # [L,B,K-1,ch]
            return P(None, b_ax, None, m_ax(3))
        if name.endswith("conv") and leaf.ndim == 5:     # [G,E,B,K-1,ch]
            return P(None, None, b_ax, None, m_ax(4))
        if name.endswith("ssm") and leaf.ndim == 5:      # [L,B,H,N,P]
            return P(None, b_ax, m_ax(2), None, None)
        if name.endswith("ssm") and leaf.ndim == 6:      # [G,E,B,H,N,P]
            return P(None, None, b_ax, m_ax(3), None, None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def opt_state_pspecs(param_specs_tree, params_shape, mesh):
    """ZeRO-1: shard optimizer moments over the data axes on top of the
    param's own spec (first unsharded, divisible dimension)."""
    ba = _batch_axes(mesh)
    sizes = axis_sizes(mesh)
    dsz = 1
    for a in ba:
        dsz *= sizes[a]

    def zero1(spec, leaf):
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (p_, d) in enumerate(zip(parts, leaf.shape)):
            if p_ is None and d % dsz == 0 and d > 0:
                parts[i] = ba if len(ba) > 1 else ba[0]
                break
        return P(*parts)

    return jax.tree_util.tree_map(zero1, param_specs_tree, params_shape,
                                  is_leaf=lambda x: isinstance(x, P))


def _probe_plan(arch: str) -> tuple:
    """(probe layer counts, extra overrides per probe, effective full L).

    XLA cost_analysis counts while-loop bodies once, so per-layer FLOP/byte/
    collective slopes are measured on small UNROLLED probe configs and
    extrapolated linearly: total = f(la) + slope * (L_full - la).
    """
    cfg = get_config(arch)
    if arch == "gemma3-27b":
        # preserve the 5:1 local:global pattern (global_every=6)
        return (6, 12), {}, cfg.n_layers
    if cfg.family == "hybrid":
        # multiples of shared_attn_every (6): 1 and 2 super-groups
        return (6, 12), {}, cfg.n_layers
    if cfg.family == "encdec":
        return (2, 4), {"scale_enc": True}, cfg.n_layers
    return (2, 4), {}, cfg.n_layers


def probe_slopes(arch: str, shape_name: str, multi_pod: bool, *,
                 zero1: bool, remat: str, extra_cfg: Optional[dict] = None,
                 mesh_kind: Optional[str] = None) -> Dict[str, float]:
    (la, lb), opts, l_full = _probe_plan(arch)
    vals = {}
    for l in (la, lb):
        ov = dict(extra_cfg or {})
        ov.update(n_layers=l, unroll=True)
        if opts.get("scale_enc"):
            ov["n_enc_layers"] = l
        rec, _ = lower_combo(arch, shape_name, multi_pod, zero1=zero1,
                             remat=remat, extra_cfg=ov, probe=False,
                             mesh_kind=mesh_kind)
        vals[l] = rec
    out = {}
    for key in ("flops_per_chip", "bytes_per_chip", "wire_bytes_per_chip"):
        fa, fb = vals[la][key], vals[lb][key]
        slope = (fb - fa) / (lb - la)
        out[key] = fa + slope * (l_full - la)
        out[key + "_slope"] = slope
    out["probe_layers"] = [la, lb]
    out["probe_compile_s"] = sum(v["compile_s"] + v["lower_s"]
                                 for v in vals.values())
    return out


def sharded_arg_bytes(shape_tree, spec_tree, mesh) -> float:
    """Analytic per-device bytes of the program arguments (the reliable
    'does it fit' number — CPU memory_analysis reports are inconsistent)."""
    sizes = axis_sizes(mesh)

    def leaf_bytes(leaf, spec):
        denom = 1
        for part in (spec or P()):
            if part is None:
                continue
            for ax in (part if isinstance(part, (tuple, list)) else (part,)):
                denom *= sizes[ax]
        n = 1
        for d in leaf.shape:
            n *= d
        return n * jnp.dtype(leaf.dtype).itemsize / denom

    total = 0.0
    leaves, _ = jax.tree_util.tree_flatten(shape_tree)
    specs, _ = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(leaves, specs):
        total += leaf_bytes(leaf, spec)
    return total


def lower_combo(arch: str, shape_name: str, multi_pod: bool,
                *, zero1: bool = True, remat: str = "full",
                extra_cfg: Optional[dict] = None, probe: bool = True,
                mesh_kind: Optional[str] = None):
    """Build + lower + compile one combination; returns (record, compiled).

    ``mesh_kind="host"`` targets whatever devices the host exposes (CI smoke
    on a forced 8-device CPU); default is the production pod/multipod mesh.
    """
    t_start = time.time()
    mesh = (make_host_mesh() if mesh_kind == "host"
            else make_production_mesh(multi_pod=multi_pod))
    ishape = INPUT_SHAPES[shape_name]
    seq_shard = shape_name == "long_500k"
    table = shd.production_rules_table(multi_pod, seq_shard=seq_shard)
    if (ishape.mode == "decode" and not seq_shard):
        pre_cfg = get_config(arch, **(extra_cfg or {}))
        msize = axis_sizes(mesh)["model"]
        if pre_cfg.n_kv_heads and pre_cfg.n_kv_heads % msize != 0:
            table["kv_seq"] = "model"

    overrides = dict(dtype="bfloat16", param_dtype="bfloat16")
    if ishape.mode == "train":
        overrides["remat"] = remat
    if extra_cfg:
        overrides.update(extra_cfg)
    cfg = get_config(arch, **overrides)
    if shape_name == "long_500k" and not cfg.supports_long_decode:
        raise SystemExit(f"SKIP: {arch} does not support long_500k (full "
                         f"attention — see DESIGN.md)")

    model = build_model(cfg)
    with shd.axis_rules(mesh, table) as rules:
        pshape = params_specs(cfg)
        pspec = shd.param_pspecs(pshape, rules)
        psharding = shd.named(pspec, mesh)
        batch_specs = input_specs(cfg, ishape.global_batch, ishape.seq_len,
                                  ishape.mode)
        bsz = ishape.global_batch
        ba = _batch_axes(mesh)
        basz = 1
        for a in ba:
            basz *= axis_sizes(mesh)[a]
        b_ax = (ba if len(ba) > 1 else ba[0]) if _div(bsz, basz) else None
        bsharding = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, P(b_ax, *([None] * (len(s.shape) - 1)))),
            batch_specs)

        if ishape.mode == "train":
            optimizer = adamw(constant(1e-4))
            state_shape = jax.eval_shape(
                lambda p: state_lib.create(p, optimizer), pshape)
            ospec = (opt_state_pspecs(pspec, pshape, mesh) if zero1 else pspec)
            state_spec = {"params": pspec,
                          "opt": {"mu": ospec, "nu": ospec},
                          "step": P()}
            state_sharding = shd.named(state_spec, mesh)
            step_fn = state_lib.make_train_step(model.loss, optimizer)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sharding, bsharding),
                             out_shardings=(state_sharding, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch_specs)
            args_bytes = sharded_arg_bytes(state_shape, state_spec, mesh)
        elif ishape.mode == "prefill":
            def fwd(params, batch):
                return model.forward(params, batch)
            jitted = jax.jit(fwd, in_shardings=(psharding, bsharding),
                             out_shardings=None)
            lowered = jitted.lower(pshape, batch_specs)
            args_bytes = sharded_arg_bytes(pshape, pspec, mesh)
        else:  # decode
            cshape = cache_specs(cfg, bsz, ishape.seq_len)
            cspec = cache_pspecs(cfg, cshape, mesh, seq_shard=seq_shard,
                                 batch=bsz)
            csharding = shd.named(cspec, mesh)
            tok_sharding = NamedSharding(mesh, P(b_ax, None))

            def serve_step(params, cache, tokens, pos):
                return model.decode_step(params, cache, tokens, pos)

            jitted = jax.jit(
                serve_step,
                in_shardings=(psharding, csharding, tok_sharding, None),
                out_shardings=(None, csharding),
                donate_argnums=(1,))
            tok_spec = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jitted.lower(pshape, cshape, tok_spec, pos_spec)
            args_bytes = (sharded_arg_bytes(pshape, pspec, mesh)
                          + sharded_arg_bytes(cshape, cspec, mesh))

        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    n_chips = mesh.devices.size
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):       # older jax: one dict per program
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:          # CPU backend may not implement it
        mem_stats = {"error": str(e)}

    coll = collective_bytes_from_hlo(compiled.as_text())

    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    # ring all-reduce moves ~2x bytes; others ~1x; per-chip wire bytes
    wire = (2.0 * coll["all-reduce"] + coll["all-gather"]
            + coll["reduce-scatter"] + coll["all-to-all"]
            + coll["collective-permute"])

    # cost_analysis counts while(scan) bodies ONCE — recover true totals from
    # unrolled two-point probes (see probe_slopes); skip for probe compiles.
    probe_stats = None
    if probe:
        probe_stats = probe_slopes(arch, shape_name, multi_pod, zero1=zero1,
                                   remat=remat, extra_cfg=extra_cfg,
                                   mesh_kind=mesh_kind)
        flops = probe_stats["flops_per_chip"]
        bytes_accessed = probe_stats["bytes_per_chip"]
        wire = probe_stats["wire_bytes_per_chip"]

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BW
    collective_s = wire / ICI_BW

    n = get_config(arch).param_count()
    n_active = get_config(arch).param_count(active_only=True)
    tokens = ishape.global_batch * (ishape.seq_len if ishape.mode != "decode"
                                    else 1)
    mult = 6 if ishape.mode == "train" else 2
    model_flops = mult * n_active * tokens
    model_flops_per_chip = model_flops / n_chips

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind or ("multipod" if multi_pod else "pod"),
        "n_chips": n_chips,
        "mode": ishape.mode,
        "zero1": zero1,
        "remat": remat if ishape.mode == "train" else None,
        "lower_s": round(t_lower - t_start, 1),
        "compile_s": round(t_compile - t_lower, 1),
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "collective_bytes": {k: v for k, v in coll.items()},
        "wire_bytes_per_chip": wire,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max(("compute", compute_s), ("memory", memory_s),
                          ("collective", collective_s), key=lambda t: t[1])[0],
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flop_ratio": (model_flops_per_chip / flops) if flops else None,
        "memory_stats": mem_stats,
        "args_gib_per_device": round(args_bytes / 2**30, 3),
        "params": n,
        "params_active": n_active,
        "probe": probe_stats,
    }
    return record, compiled


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "host"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip unrolled flop probes (multipod pass/fail runs)")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--cfg-json", default=None,
                    help="JSON dict of ArchConfig overrides (perf iterations)")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()

    extra = json.loads(args.cfg_json) if args.cfg_json else None
    record, compiled = lower_combo(
        args.arch, args.shape, args.mesh == "multipod",
        zero1=not args.no_zero1, remat=args.remat, extra_cfg=extra,
        probe=not args.no_probe,
        mesh_kind="host" if args.mesh == "host" else None)
    if args.tag:
        record["tag"] = args.tag

    print(json.dumps({k: v for k, v in record.items()
                      if k != "memory_stats"}, indent=2))
    print("memory:", record["memory_stats"])
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "a") as f:
            f.write(json.dumps(record) + "\n")


if __name__ == "__main__":
    main()
