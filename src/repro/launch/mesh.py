"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) over ("data", "model") = 256 chips.
    Multi-pod:   (2, 16, 16) over ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def axis_sizes(mesh) -> dict:
    """{axis name: size} for a mesh (the {"data": 16, "model": 16} map)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_host_mesh(*, model_axis: int = 2):
    """("data", "model") mesh over whatever devices the host exposes.

    CI / laptop smoke path: with XLA_FLAGS=--xla_force_host_platform_device_
    count=8 this yields a (4, 2) mesh, small enough to compile quickly but
    multi-device along both logical directions so every sharding rule is
    exercised for real."""
    n = jax.device_count()
    model_axis = max(1, min(model_axis, n))
    while n % model_axis:
        model_axis -= 1
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


# TPU v5e roofline constants (per chip) — see EXPERIMENTS.md §Roofline.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link
