"""Drive the full dry-run sweep: every (arch x shape x mesh) combination.

Each combo runs in its own subprocess (fresh XLA, isolation against compile
failures) and appends a JSON line to the output file. Single-pod runs carry
the unrolled flop probes (roofline terms); multi-pod runs are the pass/fail
lowering proof (+ memory analysis) without probes; ``--mesh host`` sweeps
the 8-device host platform (CI-runnable — probes are skipped there too,
host backends have no stable flop counters).

    PYTHONPATH=src python -m repro.launch.run_all_dryruns \
        --out experiments/dryrun.jsonl [--mesh pod|multipod|host|both]

``--archs``/``--shapes`` filter the sweep (comma lists) and ``--smoke``
swaps in each arch's smoke variant — the CI host-mesh sweep is

    python -m repro.launch.run_all_dryruns --mesh host --smoke \
        --archs qwen2-0.5b,mamba2-780m --shapes decode_step \
        --out experiments/dryrun.jsonl

``--profile-store PATH`` folds the sweep's roofline terms (FLOPs/HBM
bytes per chip, bound times, bottleneck) into an ``obs.ProfileStore``
next to the serve engine's measured dispatch records — the per-(arch x
shape x mesh) placement profile ROADMAP item 5 reads (optimistic
profiling for placement, one substrate with the serving loop).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config

SKIPS = {}  # (arch, shape) -> reason, filled below

for _arch in ARCH_IDS:
    _cfg = get_config(_arch)
    if not _cfg.supports_long_decode:
        SKIPS[(_arch, "long_500k")] = (
            "full-attention arch: long_500k requires sub-quadratic attention "
            "(DESIGN.md skip note)")


def combos(mesh_opt: str, archs=None, shapes=None):
    meshes = ["pod", "multipod"] if mesh_opt == "both" else [mesh_opt]
    for arch in (archs or ARCH_IDS):
        for shape in (shapes or INPUT_SHAPES):
            if (arch, shape) in SKIPS:
                continue
            for mesh in meshes:
                yield arch, shape, mesh


def _csv_filter(spec, universe, flag):
    if not spec:
        return None
    vals = [p.strip() for p in spec.split(",") if p.strip()]
    bad = [v for v in vals if v not in universe]
    if bad:
        raise SystemExit(f"{flag}: unknown entries {bad} "
                         f"(known: {sorted(universe)})")
    return vals


def store_from_jsonl(out_path: str, store_path: str) -> int:
    """Fold every dry-run record in ``out_path`` into the ProfileStore at
    ``store_path`` (keyed merge — re-runs supersede). Returns the store's
    record count."""
    from repro.obs import ProfileStore

    store = ProfileStore.load(store_path)
    with open(out_path) as f:
        for line in f:
            line = line.strip()
            if line:
                try:
                    store.add_dryrun_record(json.loads(line))
                except (json.JSONDecodeError, KeyError):
                    continue
    store.save(store_path)
    return len(store)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "host", "both"])
    ap.add_argument("--archs", default=None,
                    help="comma list of arch ids to sweep (default: all)")
    ap.add_argument("--shapes", default=None,
                    help="comma list of input shapes to sweep (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="use each arch's smoke variant (CI-sized sweep)")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the flop probes on every mesh (multipod and "
                         "host always skip them)")
    ap.add_argument("--profile-store", default=None, metavar="PATH",
                    help="also fold the sweep's roofline terms into this "
                         "obs.ProfileStore JSONL (placement profile)")
    ap.add_argument("--timeout", type=float, default=3600.0)
    ap.add_argument("--resume", action="store_true",
                    help="skip combos already present in --out")
    args = ap.parse_args()

    archs = _csv_filter(args.archs, set(ARCH_IDS), "--archs")
    shapes = _csv_filter(args.shapes, set(INPUT_SHAPES), "--shapes")

    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    todo = [c for c in combos(args.mesh, archs, shapes) if c not in done]
    print(f"{len(todo)} combos to run "
          f"({len(SKIPS)} documented skips: {sorted(set(a for a, _ in SKIPS))})",
          flush=True)
    failures = []
    for i, (arch, shape, mesh) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", args.out]
        if mesh in ("multipod", "host") or args.no_probe:
            cmd.append("--no-probe")
        if args.smoke:
            cmd += ["--cfg-json", '{"smoke": true}']
        t0 = time.time()
        print(f"[{i + 1}/{len(todo)}] {arch} {shape} {mesh} ...",
              end=" ", flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            if r.returncode != 0:
                failures.append((arch, shape, mesh, r.stderr[-2000:]))
                print(f"FAIL ({time.time() - t0:.0f}s)", flush=True)
            else:
                print(f"ok ({time.time() - t0:.0f}s)", flush=True)
        except subprocess.TimeoutExpired:
            failures.append((arch, shape, mesh, "timeout"))
            print("TIMEOUT", flush=True)

    if args.profile_store and os.path.exists(args.out):
        n = store_from_jsonl(args.out, args.profile_store)
        print(f"profile store: {args.profile_store} now holds {n} records",
              flush=True)

    print(f"\ndone: {len(todo) - len(failures)} ok, {len(failures)} failed")
    for arch, shape, mesh, err in failures:
        print(f"--- FAIL {arch} {shape} {mesh}\n{err[:800]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
