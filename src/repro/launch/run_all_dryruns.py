"""Drive the full dry-run sweep: every (arch x shape x mesh) combination.

Each combo runs in its own subprocess (fresh XLA, isolation against compile
failures) and appends a JSON line to the output file. Single-pod runs carry
the unrolled flop probes (roofline terms); multi-pod runs are the pass/fail
lowering proof (+ memory analysis) without probes.

    PYTHONPATH=src python -m repro.launch.run_all_dryruns \
        --out experiments/dryrun.jsonl [--mesh pod|multipod|both]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config

SKIPS = {}  # (arch, shape) -> reason, filled below

for _arch in ARCH_IDS:
    _cfg = get_config(_arch)
    if not _cfg.supports_long_decode:
        SKIPS[(_arch, "long_500k")] = (
            "full-attention arch: long_500k requires sub-quadratic attention "
            "(DESIGN.md skip note)")


def combos(mesh_opt: str):
    meshes = ["pod", "multipod"] if mesh_opt == "both" else [mesh_opt]
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            if (arch, shape) in SKIPS:
                continue
            for mesh in meshes:
                yield arch, shape, mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--timeout", type=float, default=3600.0)
    ap.add_argument("--resume", action="store_true",
                    help="skip combos already present in --out")
    args = ap.parse_args()

    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    todo = [c for c in combos(args.mesh) if c not in done]
    print(f"{len(todo)} combos to run "
          f"({len(SKIPS)} documented skips: {sorted(set(a for a, _ in SKIPS))})",
          flush=True)
    failures = []
    for i, (arch, shape, mesh) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", args.out]
        if mesh == "multipod":
            cmd.append("--no-probe")
        t0 = time.time()
        print(f"[{i + 1}/{len(todo)}] {arch} {shape} {mesh} ...",
              end=" ", flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            if r.returncode != 0:
                failures.append((arch, shape, mesh, r.stderr[-2000:]))
                print(f"FAIL ({time.time() - t0:.0f}s)", flush=True)
            else:
                print(f"ok ({time.time() - t0:.0f}s)", flush=True)
        except subprocess.TimeoutExpired:
            failures.append((arch, shape, mesh, "timeout"))
            print("TIMEOUT", flush=True)

    print(f"\ndone: {len(todo) - len(failures)} ok, {len(failures)} failed")
    for arch, shape, mesh, err in failures:
        print(f"--- FAIL {arch} {shape} {mesh}\n{err[:800]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
