"""Offline trace analyzer: reconstruct run behavior from a serve trace.

    PYTHONPATH=src python -m repro.launch.trace_report out.jsonl

Replays a JSONL event trace (``launch/serve.py --trace out.jsonl``) into
the summaries the raw event stream only implies:

  * **SLO-attainment timeline** — evictions bucketed over the decode-step
    clock, per tenant: attainment per bucket, so an SLO collapse shows
    WHEN it happened, not just that the run-level average dipped.
  * **Per-tenant occupancy shares** — admit/evict/preempt plus the block
    events replayed into step-weighted per-tenant cache holdings: the
    observed analogue of the allocator's planned shares.
  * **Preemption-cause breakdown** — victims grouped by (cause, tenant).
  * **Dispatch summaries** — decode-horizon geometry (K, width) and
    prefill round shapes with wall-time splits.
  * **Per-phase dispatch costs** — count / total / mean wall per phase
    from the span events; traces recorded with ``--profile`` additionally
    carry ``dispatch_profile`` events, which add the compile-vs-execute
    split and the measured-vs-roofline utilization column.
  * **Queue report** — admission wait distribution plus budget_skip /
    defer counts per tenant.
  * **Fault report** — chaos-replay traces (``launch/replay.py``) carry
    ``fault_inject`` / ``recover`` events; these are tabulated by fault
    kind and by recovery action (regenerate / retry / drop / restore).
  * **Scale report** — elastic reshapes (``scale_up`` / ``scale_down`` /
    ``migrate``): one row per reshape with units moved, capacity and mesh
    multiple after, and the reason, plus state-migration totals.

Flags: ``--json`` emits the full report as one JSON object; ``--buckets``
sets the timeline resolution; ``--validate`` checks every event against
``EVENT_SCHEMA`` first; ``--require-slo-timeline`` exits nonzero when the
trace yields no SLO timeline (the CI smoke-test assertion).

Pure stdlib + the event schema — no jax, no device bootstrap — so it runs
anywhere the trace file lands.
"""
import argparse
import json
import sys
from collections import defaultdict

from repro.obs import EVENT_SCHEMA, read_trace, validate_events


def _mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def slo_timeline(events, n_buckets: int):
    """Evictions bucketed over the decode-step clock, per tenant.

    Returns {tenant: [{"step_lo", "step_hi", "n", "met", "attainment"},
    ...]} with one entry per non-empty bucket."""
    evs = [e for e in events if e["ev"] == "evict"]
    if not evs:
        return {}
    hi = max(e["step"] for e in evs)
    width = max(hi / n_buckets, 1e-9)
    by_tenant = defaultdict(lambda: defaultdict(lambda: [0, 0]))
    for e in evs:
        b = min(int(e["step"] / width), n_buckets - 1)
        cell = by_tenant[e["tenant"]][b]
        cell[0] += 1
        cell[1] += bool(e["met"])
    out = {}
    for tenant, buckets in sorted(by_tenant.items()):
        out[tenant] = [
            {"step_lo": b * width, "step_hi": (b + 1) * width,
             "n": n, "met": met, "attainment": met / n}
            for b, (n, met) in sorted(buckets.items())]
    return out


def occupancy_shares(events):
    """Step-weighted per-tenant cache holdings, replayed from the trace.

    Admission stamps a slot's tenant and starting units (blocks for the
    paged pool, 1 slot otherwise); block_grow adds, evict / preempt
    releases. Each event integrates ``held * dt`` since the previous
    event's step, so the shares weigh holdings by how LONG they were
    held — the observed counterpart of the allocator's planned shares."""
    slot_tenant = {}
    slot_units = defaultdict(float)
    acc = defaultdict(float)           # tenant -> unit-steps
    last_step = 0.0

    def advance(step):
        nonlocal last_step
        dt = step - last_step
        if dt > 0:
            for s, t in slot_tenant.items():
                acc[t] += slot_units[s] * dt
            last_step = step
        elif dt < 0:
            last_step = step

    for e in events:
        ev = e["ev"]
        if ev not in ("admit", "evict", "preempt", "block_grow", "run_end"):
            continue
        advance(e["step"])
        slot = e.get("slot")
        if ev == "admit":
            slot_tenant[slot] = e["tenant"]
            slot_units[slot] = float(e["units"])
        elif ev == "block_grow":
            if slot in slot_tenant:
                slot_units[slot] += float(e["blocks"])
        elif ev in ("evict", "preempt"):
            slot_tenant.pop(slot, None)
            slot_units.pop(slot, None)
    total = sum(acc.values())
    return {t: {"unit_steps": v, "share": v / total if total else 0.0}
            for t, v in sorted(acc.items())}


def preemption_breakdown(events):
    """Preemption victims grouped by (cause, tenant)."""
    table = defaultdict(int)
    for e in events:
        if e["ev"] == "preempt":
            table[(e["cause"], e["tenant"])] += 1
    return [{"cause": c, "tenant": t, "n": n}
            for (c, t), n in sorted(table.items())]


def dispatch_summary(events):
    """Decode-horizon geometry and prefill shapes, with wall splits."""
    dec = [e for e in events if e["ev"] == "decode_horizon"]
    pre = [e for e in events
           if e["ev"] in ("prefill", "prefill_round")]
    shrinks = [e for e in events if e["ev"] == "horizon_shrink"]
    return {
        "decode": {
            "dispatches": len(dec),
            "mean_k": _mean(e["k"] for e in dec),
            "mean_width": _mean(e["width"] for e in dec),
            "mean_active": _mean(e["active"] for e in dec),
            "wall_s": sum(e["dur_s"] for e in dec),
        },
        "prefill": {
            "dispatches": len(pre),
            "wall_s": sum(e["dur_s"] for e in pre),
        },
        "horizon_shrinks": len(shrinks),
    }


#: span event type -> profiler phase name (the join key between the span
#: tracks and obs/prof.py's dispatch_profile events)
_PHASE_OF = {"prefill": "prefill", "prefill_round": "prefill_round",
             "decode_horizon": "decode"}


def phase_costs(events):
    """Per-phase dispatch-cost rows: count, total/mean wall from the span
    events, plus — when the trace carries ``dispatch_profile`` events
    (``launch/serve.py --profile --trace``) — the compile count/seconds
    and the mean measured-vs-roofline utilization of execute dispatches.
    ``util`` is None for traces recorded without profiling."""
    spans = defaultdict(list)
    for e in events:
        ph = _PHASE_OF.get(e["ev"])
        if ph is not None:
            spans[ph].append(float(e["dur_s"]))
    prof = defaultdict(lambda: {"utils": [], "compiles": 0, "compile_s": 0.0})
    for e in events:
        if e["ev"] == "dispatch_profile":
            p = prof[e["phase"]]
            if e.get("compile"):
                p["compiles"] += 1
                p["compile_s"] += float(e["dur_s"])
            elif e.get("util") is not None:
                p["utils"].append(float(e["util"]))
    rows = []
    for ph in sorted(set(spans) | set(prof)):
        durs = spans.get(ph, [])
        p = prof.get(ph)
        rows.append({
            "phase": ph, "count": len(durs),
            "total_ms": sum(durs) * 1e3, "mean_ms": _mean(durs) * 1e3,
            "compiles": p["compiles"] if p else 0,
            "compile_ms": p["compile_s"] * 1e3 if p else 0.0,
            "util": (_mean(p["utils"]) if p and p["utils"] else None),
        })
    return rows


def queue_report(events):
    """Admission waits plus per-tenant budget_skip / defer counts."""
    waits = defaultdict(list)
    skips = defaultdict(int)
    defers = defaultdict(int)
    for e in events:
        if e["ev"] == "admit":
            waits[e["tenant"]].append(e["wait_steps"])
        elif e["ev"] == "budget_skip":
            skips[e["tenant"]] += 1
        elif e["ev"] == "defer":
            defers[e["tenant"]] += 1
    return {t: {"admitted": len(w), "mean_wait_steps": _mean(w),
                "max_wait_steps": max(w) if w else 0.0,
                "budget_skips": skips.get(t, 0), "defers": defers.get(t, 0)}
            for t, w in sorted(waits.items())}


def fault_report(events):
    """Fault-injection and recovery tables from a chaos-replay trace.

    ``injected`` counts ``fault_inject`` events by kind; ``recoveries``
    counts ``recover`` events by (fault kind, recovery action); ``drops``
    is the subset of recoveries whose action was ``drop``. Empty dicts
    for fault-free traces."""
    injected = defaultdict(int)
    recoveries = defaultdict(int)
    drops = 0
    for e in events:
        if e["ev"] == "fault_inject":
            injected[e["kind"]] += 1
        elif e["ev"] == "recover":
            recoveries[(e["kind"], e["action"])] += 1
            drops += e["action"] == "drop"
    return {
        "injected": dict(sorted(injected.items())),
        "recoveries": [{"kind": k, "action": a, "n": n}
                       for (k, a), n in sorted(recoveries.items())],
        "drops": drops,
    }


def scale_report(events):
    """Elastic-reshape tables from a trace (serve/elastic.py).

    One row per ``scale_up`` / ``scale_down`` event — when, why, how many
    units moved, the capacity and mesh multiple after — plus migration
    totals from ``migrate`` events (blocks moved across physical pool
    growths, and the wall time spent migrating). Empty for traces without
    reshapes."""
    rows = [{"step": e["step"], "kind": e["ev"], "units": e["units"],
             "capacity": e["capacity"], "dmult": e["dmult"],
             "reason": e["reason"]}
            for e in events if e["ev"] in ("scale_up", "scale_down")]
    migs = [e for e in events if e["ev"] == "migrate"]
    return {
        "events": rows,
        "scale_ups": sum(r["kind"] == "scale_up" for r in rows),
        "scale_downs": sum(r["kind"] == "scale_down" for r in rows),
        "migrations": len(migs),
        "migrated_blocks": sum(e["blocks"] for e in migs),
        "grown_blocks": sum(e["added"] for e in migs),
        "migrate_wall_s": sum(e["dur_s"] for e in migs),
    }


def build_report(events, n_buckets: int = 8) -> dict:
    """The full analyzer output as one JSON-able dict."""
    meta = next((e for e in events if e["ev"] == "trace_meta"), None)
    run = next((e for e in events if e["ev"] == "run_start"), None)
    end = next((e for e in events if e["ev"] == "run_end"), None)
    body = [e for e in events if e["ev"] != "trace_meta"]
    return {
        "meta": {k: meta[k] for k in ("events", "dropped", "capacity")}
        if meta else None,
        "run": ({k: run[k] for k in sorted(EVENT_SCHEMA["run_start"])}
                if run else None),
        "steps": end["steps"] if end else None,
        "wall_s": end["wall_s"] if end else None,
        "slo_timeline": slo_timeline(body, n_buckets),
        "occupancy_shares": occupancy_shares(body),
        "preemptions": preemption_breakdown(body),
        "dispatches": dispatch_summary(body),
        "phase_costs": phase_costs(body),
        "queue": queue_report(body),
        "faults": fault_report(body),
        "scaling": scale_report(body),
    }


def _print_human(report: dict) -> None:
    run = report["run"] or {}
    print(f"run: backend={run.get('backend')} slots={run.get('n_slots')} "
          f"horizon={run.get('horizon')} requests={run.get('n_requests')} "
          f"steps={report['steps']} wall_s={report['wall_s'] or 0:.3f}")
    if report["meta"]:
        m = report["meta"]
        print(f"trace: {m['events']} events, {m['dropped']} dropped "
              f"(capacity {m['capacity']})")
    d = report["dispatches"]
    print(f"decode: {d['decode']['dispatches']} dispatches, "
          f"mean K {d['decode']['mean_k']:.1f}, "
          f"mean width {d['decode']['mean_width']:.1f}, "
          f"{d['decode']['wall_s']:.3f}s; "
          f"prefill: {d['prefill']['dispatches']} dispatches, "
          f"{d['prefill']['wall_s']:.3f}s; "
          f"{d['horizon_shrinks']} horizon shrinks")
    if report["phase_costs"]:
        print("\nphase costs:")
        print(f"  {'phase':<14} {'count':>5} {'total ms':>9} {'mean ms':>8} "
              f"{'compiles':>8} {'util':>6}")
        for row in report["phase_costs"]:
            util = f"{row['util']:.3g}" if row["util"] is not None else "—"
            print(f"  {row['phase']:<14} {row['count']:>5} "
                  f"{row['total_ms']:>9.1f} {row['mean_ms']:>8.2f} "
                  f"{row['compiles']:>8} {util:>6}")
    print("\noccupancy shares (step-weighted):")
    for t, s in report["occupancy_shares"].items():
        print(f"  {t:<10} {s['share']*100:5.1f}%  "
              f"({s['unit_steps']:.0f} unit-steps)")
    print("\nqueue:")
    for t, q in report["queue"].items():
        print(f"  {t:<10} admitted={q['admitted']} "
              f"mean_wait={q['mean_wait_steps']:.1f} "
              f"max_wait={q['max_wait_steps']:.0f} "
              f"budget_skips={q['budget_skips']} defers={q['defers']}")
    if report["preemptions"]:
        print("\npreemptions:")
        for row in report["preemptions"]:
            print(f"  {row['cause']:<16} {row['tenant']:<10} x{row['n']}")
    f = report.get("faults") or {}
    if f.get("injected"):
        print("\nfaults injected:")
        for kind, n in f["injected"].items():
            print(f"  {kind:<16} x{n}")
        print("recoveries:")
        for row in f["recoveries"]:
            print(f"  {row['kind']:<16} {row['action']:<12} x{row['n']}")
        print(f"requests dropped by chaos: {f['drops']}")
    s = report.get("scaling") or {}
    if s.get("events"):
        print("\nelastic reshapes:")
        print(f"  {'step':>6} {'kind':<12} {'units':>5} {'capacity':>8} "
              f"{'dmult':>5} reason")
        for row in s["events"]:
            print(f"  {row['step']:>6.0f} {row['kind']:<12} "
                  f"{row['units']:>5} {row['capacity']:>8} "
                  f"{row['dmult']:>5} {row['reason']}")
        if s["migrations"]:
            print(f"  migrations: {s['migrations']} "
                  f"({s['migrated_blocks']} blocks moved, "
                  f"{s['grown_blocks']} grown, "
                  f"{s['migrate_wall_s']*1e3:.1f} ms)")
    print("\nSLO timeline:")
    if not report["slo_timeline"]:
        print("  (no evictions in trace)")
    for t, buckets in report["slo_timeline"].items():
        cells = " ".join(
            f"[{b['step_lo']:.0f}-{b['step_hi']:.0f}) "
            f"{b['met']}/{b['n']}" for b in buckets)
        att = _mean(b["attainment"] for b in buckets)
        print(f"  {t:<10} {cells}  (mean bucket attainment {att:.2f})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="analyze a serve trace (launch/serve.py --trace)")
    ap.add_argument("trace", help="JSONL trace path")
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON")
    ap.add_argument("--buckets", type=int, default=8,
                    help="SLO-timeline resolution (step buckets)")
    ap.add_argument("--validate", action="store_true",
                    help="check every event against EVENT_SCHEMA first")
    ap.add_argument("--require-slo-timeline", action="store_true",
                    help="exit nonzero when the trace has no evictions "
                         "(CI smoke assertion)")
    args = ap.parse_args(argv)

    events, truncated = read_trace(args.trace)
    if args.validate:
        if truncated:
            print("warning: final trace line is truncated (writer was "
                  "interrupted mid-record); it was skipped", file=sys.stderr)
        problems = validate_events(events)
        if problems:
            for p in problems[:20]:
                print(f"schema violation: {p}", file=sys.stderr)
            return 2
    report = build_report(events, n_buckets=args.buckets)
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        _print_human(report)
    if args.require_slo_timeline and not report["slo_timeline"]:
        print("FAIL: trace produced no SLO timeline (no evict events)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
