"""Serving driver: batched greedy generation for any assigned architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --batch 4 --prompt-len 12 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.preset == "smoke")
    engine = ServeEngine(cfg, max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(1, cfg.vocab_size,
                                 size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for _ in range(args.batch)]
    t0 = time.perf_counter()
    out = engine.generate(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.output) for r in out)
    print(json.dumps({
        "arch": cfg.arch_id,
        "batch": args.batch,
        "new_tokens": total_new,
        "wall_s": round(dt, 2),
        "tokens_per_s": round(total_new / dt, 1),
        "sample_output": out[0].output[:8],
    }, indent=2))


if __name__ == "__main__":
    main()
