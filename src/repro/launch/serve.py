"""Serving driver: static / continuous / sharded batched generation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --engine continuous --cache paged --mesh host --slots 8 --batch 12 \
        --arrival-rate 2 --policy fcfs --verify

Engines: ``static`` runs one batch with a slot per request (one admission
round); ``continuous`` bounds the pool to ``--slots`` and joins/evicts per
decode step. ``--cache paged`` swaps the per-slot max_len cache rows for the
block-pool cache (attention families): admission is by free *blocks*
(length-proportional, ``--block-size`` positions each, ``--blocks`` total),
prompts prefill in block_size chunks packed ``--prefill-lanes`` joining
requests per jitted dispatch, shared prompt prefixes hit the content-hashed
block cache (``--no-prefix-cache`` to ablate; ``--shared-prefix N`` builds a
system-prompt-style workload and ``--min-hit-rate`` asserts the cache
worked), and decode compacts to the live slots (the summary reports the
saved rows, prefill/decode dispatch counts and wall split, the prefix-cache
hit rate, and the pool's occupancy/fragmentation).
``--decode-horizon K`` (default 8) runs K decode steps per jitted dispatch
entirely on device — on-device token selection, per-row budget/EOS stop
masks (``--eos-token``), device-resident decode state — so the summary's
``host_syncs``/``decode_dispatches`` drop ~K-fold against the per-token
loop (``--decode-horizon 1``) while outputs stay token-identical.
``--mesh host`` executes the jitted decode step TP/DP-sharded over the host
mesh (forcing an 8-device host platform when run from the CLI, like
launch/dryrun.py); decode compacts to width buckets rounded to the mesh
'data' axis on both cache backends. ``--arrival-rate R`` switches to
open-loop arrivals: request i becomes admissible at decode step i/R; 0
means all arrive at once.
``--temperature``/``--top-k`` sample on per-slot RNG lanes
(``jax.random.fold_in`` on slot id + decode step); greedy is the default.
``--verify`` re-runs the request set on a single-device static engine with a
contiguous cache and checks per-request outputs are identical — the paged
exactness invariant (greedy only).

Multi-tenant serving (serve/tenant.py): ``--tenants N`` registers tenants
t0..tN-1 and tags the request set across them (``--tenant-mix`` ratios,
round-robin interleaved); ``--slo`` / ``--slo-s`` give per-tenant latency
SLOs (comma lists, ``none`` = no target) and ``--tenant-weights`` the
fairness weights. ``--policy slo`` orders admission by SLO slack, and the
optimistic serve profiler + ``TenantAllocator`` plan per-tenant
block/lane/horizon budgets the engine enforces (``--no-tenant-alloc``
keeps the registry — tags, SLO scoring, slack policy — but drops the
budgets: the capacity-proportional baseline). The summary gains a
per-tenant block with p50/p99 latency and ``slo_attainment``; ``--verify``
still holds — tenant mechanisms reorder, they never change tokens.

Observability (src/repro/obs): ``--trace out.jsonl`` records every
scheduling decision, phase dispatch, and block-pool transition as
structured events (``--trace-format chrome`` writes a Perfetto-loadable
Chrome trace instead; ``--trace-capacity`` bounds the event ring).
``--metrics-every N`` sets the time-series sampling cadence at decode
boundaries. Analyze a JSONL trace offline with::

    PYTHONPATH=src python -m repro.launch.trace_report out.jsonl

``--profile`` attaches the dispatch profiler (obs/prof.py): per-dispatch
wall time with compile-vs-execute attribution, measured-vs-roofline
utilization gauges, and per-tenant cost shares land in the summary's
``profile`` block (and, with ``--trace``, as ``dispatch_profile`` events —
Chrome counter tracks under ``--trace-format chrome``). ``--profile-store
PATH`` closes the optimistic-profiling loop: measured per-signature costs
merge into the JSONL store, and the tenant calibrate reads MEASURED
(t_tok, t_fixed) back out of it when a fit exists (the summary's
``calibrate_source`` says which path each tenant took). Profiling is
read-only — ``--verify`` holds with it on.

Tracing and profiling off is the default and each costs one branch per
hook site, so the benchmarked decode numbers are unchanged:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --engine continuous --cache paged --mesh host --slots 8 --batch 12 \
        --tenants 2 --slo 24,none --policy slo --arrival-rate 2 --verify
"""
import os
import sys

from repro.launch._bootstrap import force_host_devices, mesh_flag

if mesh_flag(sys.argv) == "host":
    force_host_devices(os.environ.get("REPRO_SERVE_DEVICES", "8"))

import jax  # noqa: E402  (lock the device count before any repro import)

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import math         # noqa: E402

import numpy as np  # noqa: E402

from repro.configs import ARCH_IDS, get_config                    # noqa: E402
from repro.serve import (Tenant, TenantRegistry,                   # noqa: E402
                         ServeEngine, ServeRequest, plan_allocation,
                         profiles_from_requests, sharded_engine)


def make_requests(cfg, n: int, prompt_len: int, max_new: int,
                  arrival_rate: float, seed: int = 0,
                  shared_prefix: int = 0):
    """Mixed-length request set with optional open-loop arrivals.

    ``shared_prefix`` prepends the same ``shared_prefix``-token prefix to
    every prompt (a system-prompt-style workload): with the paged engine's
    prefix cache on, later requests serve those blocks from cache."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size,
                          size=shared_prefix).astype(np.int32)
    reqs = []
    for i in range(n):
        s = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        arrival = (i / arrival_rate) if arrival_rate > 0 else 0.0
        tail = rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
        reqs.append(ServeRequest(
            np.concatenate([prefix, tail]) if shared_prefix else tail,
            max_new_tokens=max_new, arrival_time=arrival))
    return reqs


def _csv(spec, n: int, flag: str):
    """Comma-list tenant flag -> n values (``none``/empty entry -> None)."""
    if not spec:
        return [None] * n
    parts = [p.strip() for p in spec.split(",")]
    if len(parts) != n:
        raise SystemExit(f"{flag} needs {n} comma-separated values "
                         f"(got {len(parts)})")
    return [None if p.lower() in ("none", "") else float(p) for p in parts]


def tag_tenants(reqs, ids, mix) -> None:
    """Deterministically interleave the request set across tenants by the
    mix ratios: request i goes to the tenant with the largest deficit
    against its target share, so a 2:1 mix tags t0,t0,t1,t0,t0,t1,..."""
    total = sum(mix)
    counts = [0] * len(ids)
    for i, r in enumerate(reqs):
        j = max(range(len(ids)),
                key=lambda k: (mix[k] * (i + 1) / total - counts[k], -k))
        r.tenant = ids[j]
        counts[j] += 1


def build_tenancy(args, reqs, n_slots, store=None):
    """Registry (+ profiler-planned allocation) for ``--tenants N``.

    The optimistic serve profiler reads each tenant's class shape off its
    tagged requests (footprint in cache units, offered concurrency) and
    the allocator plans block/lane/horizon budgets for the engine's pool
    geometry. ``--no-tenant-alloc`` keeps the registry — tags, SLO
    scoring, slack policy — without budgets (the capacity-proportional
    baseline). ``store`` (an ``obs.ProfileStore`` from
    ``--profile-store``) feeds MEASURED rate constants into the calibrate
    when its records support a fit — the knees then come from real
    dispatch costs instead of the analytic defaults."""
    n = args.tenants
    slo = _csv(args.slo, n, "--slo")
    slo_s = _csv(args.slo_s, n, "--slo-s")
    wts = _csv(args.tenant_weights, n, "--tenant-weights")
    mix = _csv(args.tenant_mix, n, "--tenant-mix")
    ids = [f"t{i}" for i in range(n)]
    registry = TenantRegistry([
        Tenant(ids[i], weight=wts[i] if wts[i] is not None else 1.0,
               slo_steps=slo[i], slo_s=slo_s[i]) for i in range(n)])
    tag_tenants(reqs, ids, [m if m is not None else 1.0 for m in mix])
    if not args.tenant_alloc:
        return registry, None, None
    if args.cache == "paged":
        blocks_per_slot = -(-args.max_len // args.block_size)
        total_units = args.blocks or (n_slots or args.batch) * blocks_per_slot
        units_for = lambda r: -(-(len(r.prompt) + r.max_new_tokens)  # noqa: E731
                                // args.block_size)
        watermark_units = math.ceil(args.watermark * total_units)
    else:
        total_units = n_slots or args.batch
        units_for = lambda r: 1                                      # noqa: E731
        watermark_units = 0
    profiles = profiles_from_requests(
        registry, reqs, total_units=total_units, units_for=units_for,
        max_k=args.decode_horizon, store=store, arch=args.arch,
        backend=args.cache)
    allocation = plan_allocation(
        registry, profiles, total_units, total_lanes=args.prefill_lanes,
        max_k=args.decode_horizon, watermark_units=watermark_units)
    return registry, allocation, profiles


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--engine", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--cache", default="contiguous",
                    choices=["contiguous", "paged"])
    ap.add_argument("--mesh", default="single", choices=["single", "host"])
    ap.add_argument("--policy", default="fcfs",
                    choices=["fcfs", "sjf", "slo"])
    ap.add_argument("--tenants", type=int, default=0,
                    help="register N tenants t0..tN-1 and tag the request "
                         "set across them (0 = single-tenant)")
    ap.add_argument("--slo", default="",
                    help="per-tenant latency SLO in decode steps, comma "
                         "list ('none' = no target), e.g. --slo 24,none")
    ap.add_argument("--slo-s", default="",
                    help="per-tenant wall-clock SLO in seconds (comma list; "
                         "scored in the stats, never scheduled on)")
    ap.add_argument("--tenant-weights", default="",
                    help="per-tenant fairness weights (comma list, default 1)")
    ap.add_argument("--tenant-mix", default="",
                    help="per-tenant request-count ratios (comma list, "
                         "default equal split), e.g. --tenant-mix 2,1")
    ap.add_argument("--no-tenant-alloc", dest="tenant_alloc",
                    action="store_false",
                    help="keep tenant tags + SLO scoring but drop the "
                         "profiler-planned budgets (capacity-proportional "
                         "baseline)")
    ap.add_argument("--batch", type=int, default=8,
                    help="number of requests in the set")
    ap.add_argument("--slots", type=int, default=4,
                    help="cache-pool slots (continuous engine)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV positions per block (paged cache)")
    ap.add_argument("--blocks", type=int, default=0,
                    help="paged pool size in blocks "
                         "(0 = slots * ceil(max_len / block_size))")
    ap.add_argument("--watermark", type=float, default=0.05,
                    help="fraction of blocks reserved at admission (paged)")
    ap.add_argument("--prefill-lanes", type=int, default=4,
                    help="joining requests prefilled per jitted chunk-round "
                         "(paged cache)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="disable content-hashed prompt-block sharing (paged)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many common prefix tokens to every "
                         "prompt (prefix-cache workload)")
    ap.add_argument("--min-hit-rate", type=float, default=None,
                    help="fail unless the prefix-cache hit rate reaches this "
                         "fraction (CI assertion)")
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="max prompt length (lengths are mixed in [len/2, len])")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--decode-horizon", type=int, default=8,
                    help="decode steps per jitted dispatch (device-resident "
                         "multi-step loop; 1 = the classic per-token loop)")
    ap.add_argument("--eos-token", type=int, default=None,
                    help="stop a request early when it emits this token id")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop arrivals per decode step (0 = all at once)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples on per-slot RNG lanes")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation for sampling (0 = full vocab)")
    ap.add_argument("--verify", action="store_true",
                    help="check outputs against a single-device static engine")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="dump a structured event trace of the run here "
                         "(analyze with repro.launch.trace_report)")
    ap.add_argument("--trace-format", default="jsonl",
                    choices=["jsonl", "chrome"],
                    help="trace file format: jsonl (trace_report) or chrome "
                         "(load in ui.perfetto.dev)")
    ap.add_argument("--trace-capacity", type=int, default=1 << 16,
                    help="event ring-buffer capacity (oldest events drop "
                         "beyond this)")
    ap.add_argument("--metrics-every", type=int, default=1,
                    help="sample the metrics time series every N decode "
                         "boundaries (0 disables series sampling)")
    ap.add_argument("--profile", action="store_true",
                    help="attach a dispatch profiler: per-dispatch wall "
                         "time with compile/execute attribution, roofline "
                         "utilization gauges, per-tenant cost shares (the "
                         "summary gains a 'profile' block; with --trace, "
                         "dispatch_profile events land in the trace)")
    ap.add_argument("--profile-store", default=None, metavar="PATH",
                    help="ProfileStore JSONL (e.g. experiments/"
                         "profiles.jsonl): read MEASURED rate constants "
                         "into the tenant calibrate when a fit exists; "
                         "with --profile, this run's per-signature costs "
                         "are merged back in")
    ap.add_argument("--elastic", action="store_true",
                    help="install an ElasticController: the engine scales "
                         "the pool up/down at horizon boundaries from the "
                         "occupancy/queue/slack gauges, re-planning tenant "
                         "budgets at every reshape")
    ap.add_argument("--elastic-max-units", type=int, default=None,
                    help="proactive scale-up ceiling in cache units "
                         "(default: the constructed pool size)")
    ap.add_argument("--elastic-min-units", type=int, default=None,
                    help="proactive scale-down floor (default: no "
                         "proactive shrink)")
    ap.add_argument("--elastic-step-units", type=int, default=8,
                    help="cache units per proactive reshape")
    ap.add_argument("--elastic-cooldown", type=float, default=16.0,
                    help="decode steps between reshapes")
    args = ap.parse_args()

    if args.verify and args.temperature > 0:
        ap.error("--verify is the greedy exactness path; drop --temperature")
    if args.policy == "slo" and args.tenants <= 0:
        ap.error("--policy slo needs --tenants N (slack comes from SLOs)")

    cfg = get_config(args.arch, smoke=args.preset == "smoke")
    n_slots = args.slots if args.engine == "continuous" else None
    n_blocks = args.blocks or None

    # requests first: the optimistic serve profiler reads each tenant's
    # class shape (footprint, concurrency) off the tagged request set.
    reqs = make_requests(cfg, args.batch, args.prompt_len, args.max_new,
                         args.arrival_rate, shared_prefix=args.shared_prefix)

    store = None
    if args.profile_store:
        from repro.obs import ProfileStore
        store = ProfileStore.load(args.profile_store)

    registry = allocation = profiles = None
    if args.tenants > 0:
        registry, allocation, profiles = build_tenancy(args, reqs, n_slots,
                                                       store=store)

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(capacity=args.trace_capacity)

    profiler = None
    if args.profile:
        from repro.obs import DispatchProfiler
        n_dev = jax.device_count() if args.mesh == "host" else 1
        profiler = DispatchProfiler(cfg, n_devices=n_dev)

    elastic = None
    if args.elastic:
        from repro.serve import ElasticController
        elastic = ElasticController(step_units=args.elastic_step_units,
                                    max_units=args.elastic_max_units,
                                    min_units=args.elastic_min_units,
                                    cooldown=args.elastic_cooldown)

    engine_kw = dict(cache=args.cache, block_size=args.block_size,
                     n_blocks=n_blocks, watermark=args.watermark,
                     prefill_lanes=args.prefill_lanes,
                     prefix_cache=args.prefix_cache,
                     temperature=args.temperature, top_k=args.top_k,
                     decode_horizon=args.decode_horizon,
                     eos_token=args.eos_token,
                     tenants=registry, allocation=allocation,
                     tracer=tracer, metrics_every=args.metrics_every,
                     profiler=profiler, elastic=elastic,
                     profile_store=store)

    if args.mesh == "host":
        engine = sharded_engine(cfg, n_slots=n_slots or args.batch,
                                max_len=args.max_len, policy=args.policy,
                                **engine_kw)
    else:
        engine = ServeEngine(cfg, max_len=args.max_len, n_slots=n_slots,
                             policy=args.policy, **engine_kw)

    out, stats = engine.run(reqs)

    trace_info = None
    if tracer is not None:
        if args.trace_format == "chrome":
            from repro.obs import write_chrome_trace
            write_chrome_trace(args.trace, tracer.events)
        else:
            tracer.dump_jsonl(args.trace)
        trace_info = {"path": args.trace, "format": args.trace_format,
                      "events": len(tracer), "dropped": tracer.dropped}

    record = {
        "arch": cfg.arch_id,
        "engine": args.engine,
        "cache": args.cache,
        "mesh": args.mesh,
        "policy": args.policy,
        "n_devices": jax.device_count(),
        "slots": n_slots or args.batch,
        "elastic": bool(elastic),
        **dataclasses.asdict(stats),
        "sample_output": out[0].output[:8],
    }
    if trace_info is not None:
        record["trace"] = trace_info
    if allocation is not None:
        record["tenant_budgets"] = {
            tid: dataclasses.asdict(s)
            for tid, s in sorted(allocation.shares.items())}
    if profiles is not None:
        record["calibrate_source"] = {
            tid: p.source for tid, p in sorted(profiles.items())}
    if profiler is not None:
        record["profile"] = profiler.summary()
        if args.profile_store:
            store.add_run(profiler, arch=args.arch, backend=args.cache,
                          mesh=args.mesh)
            store.save(args.profile_store)
            record["profile"]["store"] = {"path": args.profile_store,
                                          "records": len(store)}

    if args.verify:
        # the reference is the classic loop: single-device static engine,
        # contiguous cache, decode_horizon=1 — so --verify cross-checks the
        # multi-step horizon against per-token decoding too.
        ref_engine = ServeEngine(cfg, max_len=args.max_len, decode_horizon=1,
                                 eos_token=args.eos_token)
        ref = [ServeRequest(r.prompt.copy(), max_new_tokens=r.max_new_tokens)
               for r in out]
        ref, _ = ref_engine.run(ref)
        mismatches = [i for i, (a, b) in enumerate(zip(ref, out))
                      if a.output != b.output]
        record["verified"] = not mismatches
        if mismatches:
            record["mismatched_requests"] = mismatches
            print(json.dumps(record, indent=2))
            raise SystemExit(
                f"FAIL: {len(mismatches)} request(s) diverged from the "
                f"single-device static engine")

    if args.min_hit_rate is not None \
            and stats.prefix_hit_rate < args.min_hit_rate:
        print(json.dumps(record, indent=2))
        raise SystemExit(
            f"FAIL: prefix-cache hit rate {stats.prefix_hit_rate:.2f} below "
            f"the required {args.min_hit_rate:.2f}")

    print(json.dumps(record, indent=2))


if __name__ == "__main__":
    main()
