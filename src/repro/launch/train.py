"""End-to-end training driver.

Local mode (default): trains the selected architecture at a chosen scale on
the synthetic pipeline with the full substrate (MinIO cache, checkpointing).
Production mode is documented via the dry-run: the same ``train_step`` is
what ``repro.launch.dryrun`` lowers onto the 256/512-chip meshes.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --preset 100m --steps 300 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, DataPipeline
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # name -> ArchConfig overrides (on top of the arch's family/topology)
    "smoke": dict(),                                   # the reduced smoke cfg
    "25m": dict(n_layers=4, d_model=512, n_heads=8, n_kv_heads=4,
                head_dim=64, d_ff=1536, vocab_size=8192),
    "100m": dict(n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32768),
    "full": None,                                      # the real config
}


def build_cfg(arch: str, preset: str):
    if preset == "full":
        return get_config(arch)
    cfg = get_config(arch, smoke=True)
    if preset != "smoke":
        over = dict(PRESETS[preset])
        if cfg.family == "moe":
            over.update(n_experts=8, top_k=2, d_ff=over["d_ff"] // 4)
        if cfg.family in ("ssm", "hybrid"):
            over.pop("d_ff", None) if cfg.family == "ssm" else None
            over.update(ssm_state=64, ssm_headdim=64)
        cfg = cfg.replace(**over)
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--cache-gb", type=float, default=1.0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = build_cfg(args.arch, args.preset)
    print(f"arch={cfg.arch_id} preset={args.preset} "
          f"params={cfg.param_count() / 1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    data = DataPipeline(
        DataConfig(n_samples=4096, seq_len=args.seq,
                   vocab_size=cfg.vocab_size, preprocess_cost_s=0.0),
        batch_size=args.batch, n_workers=args.workers)
    data.set_cache_gb(args.cache_gb)

    trainer = Trainer(cfg, TrainerConfig(
        peak_lr=args.lr, total_steps=args.steps, warmup_steps=max(5, args.steps // 20),
        ckpt_path=args.ckpt, ckpt_every=max(1, args.steps // 4) if args.ckpt else 0))
    if trainer.maybe_restore():
        print(f"restored checkpoint at step {trainer.step}")

    t0 = time.time()
    hist = trainer.fit(data.batches(args.steps))
    wall = time.time() - t0
    steps = [h["step_seconds"] for h in hist[2:]] or [0.0]
    summary = {
        "arch": cfg.arch_id, "preset": args.preset,
        "params_m": cfg.param_count() / 1e6,
        "steps": len(hist), "wall_s": wall,
        "loss_first": hist[0]["loss"], "loss_last": hist[-1]["loss"],
        "ms_per_step": float(np.mean(steps)) * 1e3,
        "tokens_per_s": args.batch * args.seq / max(np.mean(steps), 1e-9),
    }
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"summary": summary, "history": hist}, f)


if __name__ == "__main__":
    main()
