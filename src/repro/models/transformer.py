"""Dense decoder-only transformer (llama3 / qwen2 / gemma3 / phi-3-vision).

Layers are stacked along a leading axis and executed with ``jax.lax.scan`` to
keep HLO size and 512-device compile times tractable. Gemma3's 5:1
local:global attention pattern is expressed as a per-layer window array that
is scanned alongside the parameters (window == 0 means global attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import layers as L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.init_attention(k1, cfg, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
        "norm1": L.init_rmsnorm(cfg.d_model, dtype),
        "norm2": L.init_rmsnorm(cfg.d_model, dtype),
    }


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "emb": L.init_embeddings(k_emb, cfg, dtype),
        "layers": jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    return params


def layer_windows(cfg) -> jnp.ndarray:
    """Per-layer sliding window (0 = full/global attention)."""
    idx = jnp.arange(cfg.n_layers)
    if cfg.sliding_window and cfg.global_every:
        is_global = (idx + 1) % cfg.global_every == 0
        return jnp.where(is_global, 0, cfg.sliding_window).astype(jnp.int32)
    if cfg.sliding_window:
        return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    return jnp.zeros((cfg.n_layers,), jnp.int32)


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------
def _layer(cfg, p, x, positions, window, kv_cache=None, cache_pos=None,
           kv_valid=None):
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    # window is a traced per-layer int32 — the mask builder must accept it.
    attn_out, new_cache = _attention_dyn_window(
        cfg, p["attn"], h, positions, window, kv_cache, cache_pos, kv_valid)
    x = x + attn_out
    h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h)
    x = shard(x, "batch", None, None)
    return x, new_cache


def _attention_dyn_window(cfg, p, x, positions, window, kv_cache, cache_pos,
                          kv_valid=None):
    """Attention with a *traced* window size (for scanned local/global mix)."""
    b, s, _ = x.shape
    if isinstance(kv_cache, L.PagedKV):
        kv_len = kv_cache.tables.shape[1] * kv_cache.k.shape[1]
    else:
        kv_len = kv_cache[0].shape[1] if kv_cache is not None else s
    scheme = L.plan_attention_scheme(cfg, b, s, kv_len)
    backend = L.plan_decode_backend(cfg, kv_cache)
    q, k, v = L._qkv(p, cfg, x, scheme=scheme)
    if cfg.pos_emb == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if backend == "paged":
        out, new_cache = L.paged_decode_attention(cfg, q, k, v, kv_cache,
                                                  positions, window, scheme,
                                                  valid=kv_valid)
        return out.reshape(b, s, -1) @ p["wo"], new_cache
    if kv_cache is not None:
        ck, cv = kv_cache
        ck, cv, k_pos, cpos = L.update_kv_cache(
            ck, cv, k, v, cache_pos,
            valid=kv_valid[:, 0] if kv_valid is not None else None)
        new_cache = (ck, cv)
        k, v = ck, cv
        mask = k_pos <= cpos
        mask &= (window == 0) | (k_pos > cpos - window)
        # [1, Sk] shared-position mask, or [B, 1, 1, Sk] per-row mask
        mask = mask[None, :] if mask.ndim == 1 else mask[:, None, None, :]
        k = shard(k, "batch", "kv_seq", None, None)
        v = shard(v, "batch", "kv_seq", None, None)
    else:
        pos = jnp.arange(s)
        mask = pos[:, None] >= pos[None, :]
        mask &= (window == 0) | (pos[:, None] - pos[None, :] < window)
        new_cache = (k, v)
    out = L.mha(q, k, v, mask, no_repeat=cfg.gqa_no_repeat, scheme=scheme)
    out = out.reshape(b, s, -1) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# banded local attention (perf knob: cfg.local_banded, EXPERIMENTS.md §Perf)
#
# Sliding-window layers never need the full S x S score matrix: queries are
# blocked into W-sized chunks, each attending to its own and the previous
# chunk only — O(S * 2W) scores instead of O(S^2). Requires a STATIC window,
# so the layer stack is split into (local x (every-1), global) groups instead
# of scanning a traced per-layer window.
# ---------------------------------------------------------------------------
def _banded_attention(cfg, p, x, positions, window: int):
    from repro.dist.sharding import current_rules, shard_spec
    from jax.sharding import PartitionSpec as P_

    b, s, _ = x.shape
    w = window
    assert s % w == 0, (s, w)
    nb = s // w
    q, k, v = L._qkv(p, cfg, x)
    if cfg.pos_emb == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    h, hd = q.shape[2], q.shape[3]
    hkv = k.shape[2]
    if h != hkv:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)

    qb = q.reshape(b, nb, w, h, hd)
    pad = jnp.zeros((b, w, h, hd), k.dtype)
    kp = jnp.concatenate([pad, k], axis=1).reshape(b, nb + 1, w, h, hd)
    vp = jnp.concatenate([pad, v], axis=1).reshape(b, nb + 1, w, h, hd)
    k2 = jnp.concatenate([kp[:, :-1], kp[:, 1:]], axis=2)   # [b,nb,2w,h,hd]
    v2 = jnp.concatenate([vp[:, :-1], vp[:, 1:]], axis=2)

    rules = current_rules()
    if rules is not None:
        msize = rules.axis_size(rules.mesh_axes("heads_flat"))
        m_ax = rules.mesh_axes("heads_flat") if h % max(msize, 1) == 0 else None
        b_ax = rules.mesh_axes("batch")
        if b % max(rules.axis_size(b_ax), 1) != 0:
            b_ax = None
        spec = P_(b_ax, None, None, m_ax, None)
        qb, k2, v2 = (shard_spec(t, spec) for t in (qb, k2, v2))

    scale = 1.0 / (hd ** 0.5)
    logits = jnp.einsum("bnqhd,bnkhd->bnhqk", qb, k2).astype(jnp.float32) * scale
    a = jnp.arange(w)[:, None]
    c = jnp.arange(2 * w)[None, :]
    band = (a < c) & (c <= a + w)                            # causal + window
    blk = jnp.arange(nb)[:, None, None]
    mask = band[None] & ((blk > 0) | (c[None] >= w))         # exclude padding
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", probs, v2)
    out = out.reshape(b, s, h * hd) @ p["wo"]
    return out


def _local_layer_banded(cfg, p, x, positions, window: int):
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    x = x + _banded_attention(cfg, p["attn"], h, positions, window)
    h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h)
    return shard(x, "batch", None, None), None


def _grouped_layout(cfg):
    """(n_groups, group_size, n_trailing) for the local/global split."""
    every = cfg.global_every
    groups = cfg.n_layers // every
    trailing = cfg.n_layers - groups * every
    return groups, every, trailing


def forward_banded(cfg, params, tokens, patch_embeds=None):
    """Grouped forward: (every-1 banded-local layers + 1 global) x groups,
    then trailing local layers. Preserves exact layer order/semantics of the
    scanned path; only the local layers' score computation is banded."""
    x = L.embed(params["emb"], cfg, tokens)
    if patch_embeds is not None:
        np_ = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, np_:]], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    groups, every, trailing = _grouped_layout(cfg)
    w = cfg.sliding_window
    stacked = params["layers"]
    gparams = jax.tree_util.tree_map(
        lambda a: a[:groups * every].reshape(groups, every, *a.shape[1:]),
        stacked)
    tparams = (jax.tree_util.tree_map(lambda a: a[groups * every:], stacked)
               if trailing else None)

    def group_body(x, gp):
        locals_ = jax.tree_util.tree_map(lambda a: a[:every - 1], gp)
        glob = jax.tree_util.tree_map(lambda a: a[every - 1], gp)
        x, _ = L.scan_layers(
            cfg, lambda c, p: _local_layer_banded(cfg, p, c, positions, w),
            x, locals_)
        x, _ = _layer(cfg, glob, x, positions, jnp.int32(0))
        return x, None

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else
                  jax.checkpoint_policies.nothing_saveable)
        group_body = jax.checkpoint(group_body, policy=policy)
    x, _ = L.scan_layers(cfg, group_body, x, gparams)
    if trailing:
        x, _ = L.scan_layers(
            cfg, lambda c, p: _local_layer_banded(cfg, p, c, positions, w),
            x, tparams)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params["emb"], cfg, x)


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------
def forward(cfg, params, tokens, patch_embeds=None, return_cache=False):
    """tokens: [B, S] int32. patch_embeds: [B, n_patches, D] (vlm stub).

    Returns logits [B, S, V] (and per-layer (k, v) stacks if return_cache).
    """
    if (cfg.local_banded and cfg.sliding_window and cfg.global_every
            and tokens.shape[1] % cfg.sliding_window == 0):
        out = forward_banded(cfg, params, tokens, patch_embeds)
        if return_cache:
            raise NotImplementedError("banded path has no prefill cache yet")
        return out
    x = L.embed(params["emb"], cfg, tokens)
    if patch_embeds is not None:
        # VLM stub frontend: image patch embeddings occupy the sequence prefix.
        np_ = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, np_:]], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    windows = layer_windows(cfg)

    def body(x, scanned):
        p, w = scanned
        return _layer(cfg, p, x, positions, w)

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else
                  jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)

    x, caches = L.scan_layers(cfg, body, x, (params["layers"], windows))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    if return_cache:
        return logits, caches
    return logits


def loss_fn(cfg, params, batch):
    """batch: {tokens, labels[, patch_embeds]}. Mean next-token CE."""
    logits = forward(cfg, params, batch["tokens"],
                     patch_embeds=batch.get("patch_embeds"))
    mask = batch.get("loss_mask")
    if cfg.family == "vlm" and mask is None:
        s = batch["labels"].shape[1]
        mask = jnp.broadcast_to(jnp.arange(s)[None, :] >= cfg.n_patches,
                                batch["labels"].shape)
    return L.cross_entropy(logits, batch["labels"], mask)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    shape = (cfg.n_layers, batch, max_len, nkv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_cache(cfg, n_blocks: int, block_size: int, dtype=None):
    """Block-pool decode cache: ``n_blocks`` blocks of ``block_size`` KV
    positions shared by all requests (serve/paged.py's BlockManager carves
    them up); the per-request block tables live outside the pytree."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    shape = (cfg.n_layers, n_blocks, block_size, nkv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_prefill_state(cfg, batch: int = 1):
    """Cross-chunk prefill carry (none for dense attention)."""
    return None


def prefill_chunk_layout(start, n_valid, b: int, c: int):
    """Per-token (positions [B, C], valid [B, C] | None, last-index [B])
    for a (lane-batched) prefill chunk. ``start`` is a scalar (one request)
    or an int32 [B] vector of per-lane first positions; ``n_valid`` (int32
    [B] or None) counts the real tokens per lane — the tail of a short
    final chunk is padding whose K/V writes must be dropped and whose
    logits are discarded."""
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        start = jnp.full((b,), start, jnp.int32)
    positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    if n_valid is None:
        return positions, None, jnp.full((b,), c - 1, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < n_valid[:, None]
    return positions, valid, jnp.clip(n_valid - 1, 0, c - 1)


def paged_prefill_chunk(cfg, params, cache, tokens, start, tables,
                        state=None, cap_tokens: int = 0, n_valid=None,
                        cap_rows=None):
    """Prefill one prompt chunk per lane into the paged cache.

    tokens: [P, C] — one ``block_size`` slice of P joining requests' prompts
    (one jitted dispatch covers a whole chunk-round; P == 1 is the
    single-request case); start: int32 scalar or [P] — each lane's first
    logical position; n_valid: int32 [P] or None — real tokens per lane
    (short final chunks are padded to C; pad positions write nothing and
    their logits are ignored); tables: [P, MB] — each request's block table
    (blocks covering its [0, start + n_valid) must already be assigned).
    ``cap_rows`` is accepted for signature parity with the MoE family and
    ignored. The chunk's K/V is appended through the table and attention
    spans every cached position, so chaining chunks reproduces the one-pass
    forward without ever materializing a contiguous max_len row. Returns
    (per-lane last-valid-position logits [P, 1, V], new cache, state).
    """
    x = L.embed(params["emb"], cfg, tokens)
    b, c, _ = x.shape
    positions, valid, last = prefill_chunk_layout(start, n_valid, b, c)
    windows = layer_windows(cfg)

    def body(x, scanned):
        p, w, ck, cv = scanned
        x, new_kv = _layer(cfg, p, x, positions, w,
                           kv_cache=L.PagedKV(ck, cv, tables),
                           kv_valid=valid)
        return x, new_kv

    x, (new_k, new_v) = L.scan_layers(
        cfg, body, x, (params["layers"], windows, cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    logits = jnp.take_along_axis(logits, last[:, None, None], axis=1)
    return logits, {"k": new_k, "v": new_v}, None


def paged_decode_step(cfg, params, cache, tokens, pos, tables,
                      write_valid=None):
    """One paged decode step. tokens: [B, 1]; pos: int32 [B] per-row
    positions; tables: [B, MB] block tables (padding rows are all -1 and
    decode inert garbage that is never read); write_valid: [B] bool or None
    — False rows compute but write no KV (frozen rows of a multi-step
    decode horizon). Returns (logits, new_cache)."""
    x = L.embed(params["emb"], cfg, tokens)
    b = x.shape[0]
    positions = L.decode_positions(b, pos)
    windows = layer_windows(cfg)
    kv_valid = None if write_valid is None else write_valid[:, None]

    def body(x, scanned):
        p, w, ck, cv = scanned
        x, new_kv = _layer(cfg, p, x, positions, w,
                           kv_cache=L.PagedKV(ck, cv, tables),
                           kv_valid=kv_valid)
        return x, new_kv

    x, (new_k, new_v) = L.scan_layers(
        cfg, body, x, (params["layers"], windows, cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    return logits, {"k": new_k, "v": new_v}


def decode_step(cfg, params, cache, tokens, pos, write_valid=None):
    """One decode step. tokens: [B, 1]; pos: scalar int32 (all rows at the
    same position) or int32 [B] (per-row positions, continuous batching);
    write_valid: [B] bool or None — False rows compute but write no KV
    (frozen rows of a multi-step decode horizon; needs vector pos).

    Returns (logits [B, 1, V], new_cache).
    """
    x = L.embed(params["emb"], cfg, tokens)
    b = x.shape[0]
    positions = L.decode_positions(b, pos)
    windows = layer_windows(cfg)
    kv_valid = None if write_valid is None else write_valid[:, None]

    def body(x, scanned):
        p, w, ck, cv = scanned
        x, new_kv = _layer(cfg, p, x, positions, w, kv_cache=(ck, cv),
                           cache_pos=pos, kv_valid=kv_valid)
        return x, new_kv

    x, (new_k, new_v) = L.scan_layers(
        cfg, body, x, (params["layers"], windows, cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    return logits, {"k": new_k, "v": new_v}
