"""Unified model API.

``build_model(cfg)`` returns a ``Model`` facade with the same five entry
points for every architecture family:

    init(rng) -> params
    loss(params, batch) -> scalar           (training objective)
    forward(params, batch) -> logits        (prefill / full-sequence)
    init_cache(batch, max_len) -> cache
    decode_step(params, cache, tokens, pos) -> (logits, cache)

``input_specs(cfg, shape, mode)`` produces ``jax.ShapeDtypeStruct`` stand-ins
for every input of the corresponding step — weak-type-correct, shardable, and
allocation-free — which is what the multi-pod dry-run lowers against.
``make_batch`` materializes the same structure with real (random) arrays for
smoke tests and the live runtime.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, hybrid, mamba2, moe, transformer

_FAMILY_MODULES = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "ssm": mamba2,
    "hybrid": hybrid,
    "encdec": encdec,
}


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    forward: Callable
    init_cache: Callable
    decode_step: Callable
    module: Any
    # paged decode-cache entry points (attention families only; None for the
    # recurrent families whose state is O(1) and has nothing to page):
    init_paged_cache: Any = None        # (n_blocks, block_size) -> cache
    paged_decode_step: Any = None       # (params, cache, tokens, pos, tables)
    paged_prefill_chunk: Any = None     # (params, cache, tokens, start,
                                        #  tables, state, cap_tokens,
                                        #  n_valid, cap_rows) — lane-batched:
                                        #  tokens [P, C] packs chunks from P
                                        #  joining requests into one dispatch
    paged_prefill_state: Any = None     # (batch) -> cross-chunk carry


def build_model(cfg: ArchConfig) -> Model:
    mod = _FAMILY_MODULES[cfg.family]

    def init(rng):
        return mod.init_params(cfg, rng)

    def loss(params, batch):
        return mod.loss_fn(cfg, params, batch)

    def forward(params, batch):
        if cfg.family == "encdec":
            return mod.forward(cfg, params, batch["tokens"], batch["frames"])
        if cfg.family == "vlm":
            return mod.forward(cfg, params, batch["tokens"],
                               patch_embeds=batch.get("patch_embeds"))
        return mod.forward(cfg, params, batch["tokens"])

    def init_cache(batch, max_len, dtype=None):
        return mod.init_cache(cfg, batch, max_len, dtype)

    def decode_step(params, cache, tokens, pos, write_valid=None):
        # write_valid (frozen-row KV-write mask of a multi-step decode
        # horizon) exists for the attention families; recurrent state has no
        # positional write to mask, so the plain signature is kept there.
        if write_valid is None:
            return mod.decode_step(cfg, params, cache, tokens, pos)
        return mod.decode_step(cfg, params, cache, tokens, pos,
                               write_valid=write_valid)

    paged = {}
    if hasattr(mod, "init_paged_cache"):
        paged = dict(
            init_paged_cache=(
                lambda n_blocks, block_size, dtype=None:
                mod.init_paged_cache(cfg, n_blocks, block_size, dtype)),
            paged_decode_step=(
                lambda params, cache, tokens, pos, tables, write_valid=None:
                mod.paged_decode_step(cfg, params, cache, tokens, pos,
                                      tables, write_valid=write_valid)),
            paged_prefill_chunk=(
                lambda params, cache, tokens, start, tables, state=None,
                cap_tokens=0, n_valid=None, cap_rows=None:
                mod.paged_prefill_chunk(cfg, params, cache, tokens, start,
                                        tables, state, cap_tokens,
                                        n_valid=n_valid, cap_rows=cap_rows)),
            paged_prefill_state=(
                lambda batch=1: mod.paged_prefill_state(cfg, batch)),
        )

    return Model(cfg=cfg, init=init, loss=loss, forward=forward,
                 init_cache=init_cache, decode_step=decode_step, module=mod,
                 **paged)


# ---------------------------------------------------------------------------
# input specs / batches
# ---------------------------------------------------------------------------
def _extras_struct(cfg: ArchConfig, batch: int, dtype) -> Dict[str, Any]:
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_seq, cfg.d_model), dtype)
    if cfg.family == "vlm":
        extras["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), dtype)
    return extras


def input_specs(cfg: ArchConfig, batch: int, seq_len: int,
                mode: str = "train") -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the given step's data inputs.

    mode: 'train' (tokens+labels), 'prefill' (tokens), 'decode'
    (single token; the KV/state cache is produced via ``cache_specs``).
    """
    i32 = jnp.int32
    dtype = jnp.dtype(cfg.dtype)
    if mode == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((batch, seq_len), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq_len), i32),
        }
        specs.update(_extras_struct(cfg, batch, dtype))
        return specs
    if mode == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((batch, seq_len), i32)}
        specs.update(_extras_struct(cfg, batch, dtype))
        return specs
    if mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), i32)}
    raise ValueError(mode)


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    """ShapeDtypeStructs for the decode cache (via eval_shape — no alloc)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def paged_cache_specs(cfg: ArchConfig, n_blocks: int, block_size: int):
    """ShapeDtypeStructs for the paged (block-pool) decode cache."""
    model = build_model(cfg)
    if model.init_paged_cache is None:
        raise ValueError(f"family {cfg.family!r} has no paged decode cache")
    return jax.eval_shape(lambda: model.init_paged_cache(n_blocks,
                                                         block_size))


def params_specs(cfg: ArchConfig):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def make_batch(cfg: ArchConfig, batch: int, seq_len: int, rng,
               mode: str = "train") -> Dict[str, Any]:
    """Materialize a random batch matching ``input_specs``."""
    specs = input_specs(cfg, batch, seq_len, mode)
    out = {}
    for name, s in specs.items():
        rng, k = jax.random.split(rng)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size,
                                           dtype=s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, dtype=s.dtype) * 0.02
    return out
