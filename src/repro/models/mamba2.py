"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of length Q; within a chunk the dual (attention-like) quadratic form
is used, across chunks a low-rank state [H, N, P] is carried by a scan. This
is exactly the block decomposition the paper derives, and it is what the
Pallas ``ssd_scan`` kernel implements on TPU (grid iterates chunks, carrying
the inter-chunk state in VMEM scratch).

Decode carries the recurrent state directly: h <- a*h + dt*(B (x) x),
y = C.h + D*x — O(1) per token, which is why mamba2 runs the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import layers as L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _dims(cfg):
    di = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h, p = cfg.n_ssm_heads, cfg.ssm_headdim
    conv_ch = di + 2 * g * n
    return di, g, n, h, p, conv_ch


def init_block(key, cfg, dtype):
    di, g, n, h, p, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * g * n + h
    return {
        "in_proj": L._init_dense(ks[0], (d, d_in_proj), dtype),
        "conv_w": L._init_dense(ks[1], (cfg.ssm_conv, conv_ch), dtype, scale=0.3),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "D": jnp.ones((h,), dtype),
        "gate_norm": L.init_rmsnorm(di, dtype),
        "out_proj": L._init_dense(ks[3], (di, d), dtype),
        "norm": L.init_rmsnorm(d, dtype),
    }


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        "emb": L.init_embeddings(k_emb, cfg, dtype),
        "layers": jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------
def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return y + b


def _project(cfg, p, x):
    """Shared input projection/split for both train and decode paths.

    Returns z [.., di], xBC [.., conv_ch] (pre-conv), dt [.., H].
    """
    di, g, n, h, _, conv_ch = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + conv_ch]
    dt = zxbcdt[..., di + conv_ch:]
    return z, xBC, dt


def _split_xbc(cfg, xBC):
    di, g, n, h, ph, _ = _dims(cfg)
    x = xBC[..., :di]
    B = xBC[..., di:di + g * n]
    C = xBC[..., di + g * n:]
    shp = x.shape[:-1]
    x = x.reshape(*shp, h, ph)
    B = B.reshape(*shp, g, n)
    C = C.reshape(*shp, g, n)
    # broadcast groups -> heads
    rep = h // g
    B = jnp.repeat(B, rep, axis=-2)
    C = jnp.repeat(C, rep, axis=-2)
    return x, B, C


def ssd_chunked(xdt, a_log, B, C, chunk: int = 256):
    """Chunked SSD scan (pure-jnp reference path used by the model).

    xdt: [B, S, H, P] (dt-scaled inputs);  a_log: [B, S, H] (log decay);
    B, C: [B, S, H, N].  Returns y: [B, S, H, P].
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    q = chunk if (s % chunk == 0 and s >= chunk) else _best_chunk(s)
    nc = s // q
    xdt = xdt.reshape(b, nc, q, h, p)
    a_log = a_log.reshape(b, nc, q, h)
    Bm = B.reshape(b, nc, q, h, n)
    Cm = C.reshape(b, nc, q, h, n)

    lc = jnp.cumsum(a_log, axis=2)                   # [b,nc,q,h] within-chunk
    l_last = lc[:, :, -1:, :]                        # total chunk decay

    # intra-chunk (dual/attention form)
    scores = jnp.einsum("bcihn,bcjhn->bchij", Cm, Bm)
    li = lc.transpose(0, 1, 3, 2)                    # [b,nc,h,q]
    # valid (j <= i) exponents are <= 0; clamp the masked ones to avoid
    # inf * 0 -> NaN in the backward pass of the where().
    decay = jnp.exp(jnp.minimum(li[..., :, None] - li[..., None, :], 0.0))
    # decay[b,c,h,i,j] = exp(l_i - l_j), mask j<=i
    idx = jnp.arange(q)
    mask = idx[:, None] >= idx[None, :]
    m = jnp.where(mask, scores * decay, 0.0)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", m, xdt)

    # chunk states: S_c = sum_j exp(l_last - l_j) B_j (x) xdt_j
    w = jnp.exp(l_last - lc)                         # [b,nc,q,h]
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", Bm, w, xdt)

    # inter-chunk recurrence: T_c = gamma_c * T_{c-1} + S_c
    gamma = jnp.exp(l_last[:, :, 0, :])              # [b,nc,h]

    def scan_fn(t_prev, inp):
        g_c, s_c = inp
        t_new = g_c[:, :, None, None] * t_prev + s_c
        return t_new, t_prev                          # emit state *entering* chunk

    t0 = jnp.zeros((b, h, n, p), xdt.dtype)
    _, t_in = jax.lax.scan(scan_fn,
                           t0,
                           (gamma.swapaxes(0, 1), states.swapaxes(0, 1)))
    t_in = t_in.swapaxes(0, 1)                       # [b,nc,h,n,p]

    y_inter = jnp.einsum("bcihn,bcih,bchnp->bcihp", Cm, jnp.exp(lc), t_in)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y


def _best_chunk(s: int) -> int:
    for q in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if s % q == 0:
            return q
    return 1


# ---------------------------------------------------------------------------
# block forward
# ---------------------------------------------------------------------------
def block_fwd(cfg, p, x):
    """x: [B, S, D] -> [B, S, D] (pre-norm residual applied by caller)."""
    di, g, n, h, ph, conv_ch = _dims(cfg)
    z, xBC, dt = _project(cfg, p, x)
    xBC = jax.nn.silu(causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    xs, B, C = _split_xbc(cfg, xBC)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a_log = (dt * A).astype(jnp.float32)             # log decay, [B,S,H]
    xdt = (xs.astype(jnp.float32) * dt[..., None])

    if cfg.use_pallas:
        from repro.kernels import ops as kops
        y = kops.ssd_scan(xdt, a_log, B.astype(jnp.float32), C.astype(jnp.float32),
                          chunk=cfg.ssm_chunk)
    else:
        y = ssd_chunked(xdt, a_log, B.astype(jnp.float32), C.astype(jnp.float32),
                        chunk=cfg.ssm_chunk)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(*x.shape[:-1], di)
    y = shard(y, "batch", None, "inner_flat")

    y = L.rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def block_decode(cfg, p, x, conv_state, ssm_state):
    """Single-token recurrent step.

    x: [B, 1, D]; conv_state: [B, K-1, conv_ch]; ssm_state: [B, H, N, P].
    """
    di, g, n, h, ph, conv_ch = _dims(cfg)
    z, xBC, dt = _project(cfg, p, x)                 # [B,1,...]
    # conv via state buffer
    full = jnp.concatenate([conv_state, xBC], axis=1)        # [B, K, C]
    y_conv = jnp.einsum("bkc,kc->bc", full, p["conv_w"]) + p["conv_b"]
    new_conv = full[:, 1:, :]
    xBC = jax.nn.silu(y_conv)[:, None, :]
    xs, B, C = _split_xbc(cfg, xBC)                  # [B,1,H,P] / [B,1,H,N]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)[:, 0]                        # [B,H]
    xdt = (xs.astype(jnp.float32) * dt[..., None])[:, 0]      # [B,H,P]
    Bv, Cv = B.astype(jnp.float32)[:, 0], C.astype(jnp.float32)[:, 0]  # [B,H,N]

    new_state = (a[..., None, None] * ssm_state
                 + jnp.einsum("bhn,bhp->bhnp", Bv, xdt))
    y = jnp.einsum("bhn,bhnp->bhp", Cv, new_state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)[:, 0]
    y = y.astype(x.dtype).reshape(x.shape[0], 1, di)

    y = L.rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_conv, new_state.astype(ssm_state.dtype)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------
def forward(cfg, params, tokens):
    x = L.embed(params["emb"], cfg, tokens)

    def body(x, p):
        h = L.rmsnorm(x, p["norm"], cfg.norm_eps)
        x = x + block_fwd(cfg, p, h)
        return shard(x, "batch", None, None), None

    if cfg.remat != "none":
        body = jax.checkpoint(body)

    x, _ = L.scan_layers(cfg, body, x, params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params["emb"], cfg, x)


def loss_fn(cfg, params, batch):
    logits = forward(cfg, params, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    di, g, n, h, p, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, h, n, p), jnp.float32),
    }


def decode_step(cfg, params, cache, tokens, pos):
    x = L.embed(params["emb"], cfg, tokens)

    def body(x, scanned):
        p, conv_s, ssm_s = scanned
        h = L.rmsnorm(x, p["norm"], cfg.norm_eps)
        out, new_conv, new_ssm = block_decode(cfg, p, h, conv_s, ssm_s)
        return x + out, (new_conv, new_ssm)

    x, (new_conv, new_ssm) = L.scan_layers(
        cfg, body, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    return logits, {"conv": new_conv, "ssm": new_ssm}
