"""Core layer library: GQA attention (RoPE / sinusoidal, sliding window, QKV
bias), SwiGLU MLP, RMSNorm / LayerNorm, embeddings.

All layers are pure functions over parameter dicts; initialization functions
return plain dict pytrees so layers can be stacked (``jax.lax.scan`` over a
leading layer axis) without framework machinery.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard


def scan_layers(cfg, body, carry, xs):
    """lax.scan over stacked layers, or an unrolled Python loop when
    ``cfg.unroll`` (used by the dry-run's flop probes — XLA cost_analysis
    counts while-loop bodies exactly once, so probes must unroll)."""
    if not cfg.unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    outs = []
    for i in range(n):
        sl = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, out = body(carry, sl)
        outs.append(out)
    if all(o is None for o in outs):
        return carry, None
    stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *outs)
    return carry, stacked


def _init_dense(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x, weight, eps: float):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_rmsnorm(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)          # stored as (1 + w) offset form


# ---------------------------------------------------------------------------
# Positional embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                 # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs    # [B, S, D/2]
    cos, sin = jnp.cos(angles)[:, :, None, :], jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(max_len: int, d: int) -> jax.Array:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((max_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    nhe = cfg.n_heads_eff
    ks = jax.random.split(key, 4)
    wq = _init_dense(ks[0], (d, nh * hd), dtype)
    wo = _init_dense(ks[3], (nh * hd, d), dtype)
    if nhe > nh:
        # Head padding (perf knob): extra Q heads whose wo rows are zero, so
        # the function is unchanged at init while heads shard evenly. Padding
        # must go INSIDE each KV group (head h maps to kv h // g), so pad the
        # per-group head count g -> g_new and keep groups contiguous.
        assert nh % nkv == 0 and nhe % nkv == 0, (nh, nhe, nkv)
        g_old, g_new = nh // nkv, nhe // nkv
        wq4 = wq.reshape(d, nkv, g_old, hd)
        wq4 = jnp.pad(wq4, ((0, 0), (0, 0), (0, g_new - g_old), (0, 0)))
        wq = wq4.reshape(d, nhe * hd)
        wo4 = wo.reshape(nkv, g_old, hd, d)
        wo4 = jnp.pad(wo4, ((0, 0), (0, g_new - g_old), (0, 0), (0, 0)))
        wo = wo4.reshape(nhe * hd, d)
    p = {
        "wq": wq,
        "wk": _init_dense(ks[1], (d, nkv * hd), dtype),
        "wv": _init_dense(ks[2], (d, nkv * hd), dtype),
        "wo": wo,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nhe * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


#: sentinel for "derive the attention scheme locally" (legacy call sites);
#: layer entry points thread ONE scheme per layer instead (ROADMAP item #4).
_DERIVE = object()


def plan_attention_scheme(cfg, b: int, s: int, kv_len: int):
    """Derive the single attention scheme for one layer call.

    The head count handed to ``attention_scheme`` is the one the score einsum
    actually contracts over — pre-repeat KV heads under ``gqa_no_repeat``,
    effective (padded) Q heads otherwise — and ``kv_len`` is the attended
    length (cache length in decode, sequence length in prefill). Deriving
    once here and passing the scheme down guarantees the q/kv layouts agree
    at every constraint site within the layer.
    """
    from repro.dist.sharding import attention_scheme
    nh, nkv = cfg.n_heads_eff, cfg.n_kv_heads
    g = nh // max(nkv, 1)
    heads = nkv if (cfg.gqa_no_repeat and g > 1) else nh
    return attention_scheme(b, s, heads, kv_len)


def _qkv(p, cfg, x, scheme=_DERIVE):
    from repro.dist.sharding import attention_scheme, current_rules, shard_spec
    b, s, _ = x.shape
    hd, nh, nkv = cfg.resolved_head_dim, cfg.n_heads_eff, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    # Constrain IMMEDIATELY after the head reshape: downstream elementwise ops
    # (RoPE) must run on the final layout, or SPMD inserts replicate-reshard
    # pairs ("involuntary full rematerialization").
    if scheme is _DERIVE:
        scheme = attention_scheme(b, s, nh, s)
    rules = current_rules()
    if scheme is not None:
        q = shard_spec(q, scheme["q"])
        kv_spec = scheme["kv"]
        # pre-repeat KV: drop the head axis if nkv is not divisible
        parts = list(kv_spec)
        if parts[2] is not None and nkv % rules.axis_size(parts[2]) != 0:
            parts[2] = None
        k = shard_spec(k, jax.sharding.PartitionSpec(*parts))
        v = shard_spec(v, jax.sharding.PartitionSpec(*parts))
    return q, k, v


def attention_weights_mask(q_pos, k_pos, *, causal: bool,
                           window: int = 0):
    """Boolean mask [.., Sq, Sk]: True = attend."""
    mask = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    return mask


def mha(q, k, v, mask, *, use_pallas: bool = False, causal: bool = False,
        window: int = 0, no_repeat: bool = False, scheme=_DERIVE):
    """Grouped-query attention core.

    q: [B, Sq, Hq, D], k/v: [B, Sk, Hkv, D], mask broadcastable to [Sq, Sk]
    (or [B, 1, 1, Sk] for per-row decode positions).

    KV heads are repeated to the full head count before the score einsum so
    the head dimension shards cleanly over the 'model' mesh axis (GQA head
    counts rarely divide it). The sharding scheme (heads / extra-batch /
    q-seq) is threaded in from the layer entry point (one scheme per layer);
    legacy callers that omit it get a locally derived one — see
    dist.sharding.attention_scheme.
    """
    from repro.dist.sharding import attention_scheme, shard_spec

    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    g = hq // hkv
    no_repeat = no_repeat and g > 1
    if g > 1 and not no_repeat:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if scheme is _DERIVE:
        scheme = attention_scheme(b, sq, hkv if no_repeat else hq, k.shape[1])
    if scheme is not None:
        k = shard_spec(k, scheme["kv"])
        v = shard_spec(v, scheme["kv"])
    scale = 1.0 / math.sqrt(d)
    if no_repeat:
        # grouped einsum: KV stays at hkv heads (sharded over 'model'), no
        # repeat materialization/reshard of the cache (decode perf knob).
        qg = q.reshape(b, sq, hkv, g, d)
        if scheme is not None:
            qs = scheme["q"]
            qg = shard_spec(qg, jax.sharding.PartitionSpec(
                qs[0], qs[1], qs[2], None, None))
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
        if mask is not None:
            if mask.ndim == 4:                      # [B, 1|H, 1|Q, K]
                m5 = mask[:, :, None]
            elif mask.ndim >= 3:
                m5 = mask
            else:
                m5 = mask[None]
            logits = jnp.where(m5, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
        out = out.reshape(b, sq, hq, d)
        if scheme is not None:
            out = shard_spec(out, scheme["q"])
        return out
    if scheme is not None:
        q = shard_spec(q, scheme["q"])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if scheme is not None:
        logits = shard_spec(logits, scheme["logits"])
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    if scheme is not None:
        out = shard_spec(out, scheme["q"])
    return out


# ---------------------------------------------------------------------------
# Paged decode-attention backend
#
# The serving mirror of Synergy's memory-sensitivity claim: a request holds
# ceil(len / block_size) fixed-size KV blocks behind a per-request block
# table instead of a full max_len cache row (serve/paged.py manages the
# pool). The layer-level backend is selected per layer next to
# plan_attention_scheme: "contiguous" threads the classic (ck, cv) cache,
# "paged" threads a PagedKV and routes through paged_decode_attention.
# ---------------------------------------------------------------------------
DECODE_BACKENDS = ("contiguous", "paged")


class PagedKV(NamedTuple):
    """One layer's paged decode cache: block-pool K/V plus the block table.

    k, v: [n_blocks, block_size, Hkv, D] — the shared block pool.
    tables: [B, max_blocks] int32 — row b's logical position p lives in block
    ``tables[b, p // block_size]`` at offset ``p % block_size``; -1 marks an
    unassigned table column (padding rows read nothing and write nowhere).
    """
    k: jax.Array
    v: jax.Array
    tables: jax.Array


def plan_decode_backend(cfg, kv_cache) -> str:
    """Select the decode-attention backend for one layer call.

    The backend follows the cache representation the caller threads in and
    must agree with ``cfg.decode_attention`` — a paged cache reaching a layer
    whose config says contiguous (or vice versa) is a wiring bug, not a
    fallback case.
    """
    if cfg.decode_attention not in DECODE_BACKENDS:
        raise ValueError(
            f"unknown decode_attention {cfg.decode_attention!r}; "
            f"known: {DECODE_BACKENDS}")
    backend = "paged" if isinstance(kv_cache, PagedKV) else "contiguous"
    if kv_cache is not None and backend != cfg.decode_attention:
        raise ValueError(
            f"decode cache is {backend} but cfg.decode_attention is "
            f"{cfg.decode_attention!r}")
    return backend


def paged_kv_write(pkv: PagedKV, k, v, positions, valid=None) -> PagedKV:
    """Write k/v [B, C, Hkv, D] at logical ``positions`` [B, C] through the
    block table. Rows whose table has no block for a position (padding rows,
    ``tables[b, p // bs] < 0``) are dropped, never scattered into a live
    block; ``valid`` [B, C] additionally drops padded lane positions of a
    batched prefill chunk (a short final chunk padded to block_size must not
    scatter garbage into its own — or, prefix-shared, anyone else's —
    blocks)."""
    nb, bs = pkv.k.shape[:2]
    mb = pkv.tables.shape[1]
    p = jnp.asarray(positions, jnp.int32)
    col = jnp.clip(p // bs, 0, mb - 1)           # pad positions may overrun
    blk = jnp.take_along_axis(pkv.tables, col, axis=1)
    blk = jnp.where((blk >= 0) & (p // bs < mb), blk, nb)  # oob -> dropped
    if valid is not None:
        blk = jnp.where(valid, blk, nb)
    off = p % bs
    nk = pkv.k.at[blk, off].set(k.astype(pkv.k.dtype), mode="drop")
    nv = pkv.v.at[blk, off].set(v.astype(pkv.v.dtype), mode="drop")
    return PagedKV(nk, nv, pkv.tables)


def paged_kv_gather(pkv: PagedKV):
    """Materialize each row's pages: -> (k [B, MB*BS, Hkv, D], v likewise,
    k_pos [B, MB*BS] logical positions, valid [B, MB*BS] assigned-block
    mask). Unassigned table entries gather block 0 and are masked off."""
    nb, bs = pkv.k.shape[:2]
    b, mb = pkv.tables.shape
    safe = jnp.maximum(pkv.tables, 0)
    kg = pkv.k[safe].reshape(b, mb * bs, *pkv.k.shape[2:])
    vg = pkv.v[safe].reshape(b, mb * bs, *pkv.v.shape[2:])
    k_pos = jnp.broadcast_to(jnp.arange(mb * bs, dtype=jnp.int32)[None],
                             (b, mb * bs))
    valid = jnp.repeat(pkv.tables >= 0, bs, axis=1)
    return kg, vg, k_pos, valid


def paged_decode_attention(cfg, q, k, v, pkv: PagedKV, positions, window,
                           scheme, valid=None):
    """The "paged" decode-attention backend: write this call's (post-RoPE)
    k/v [B, C, Hkv, D] at ``positions`` [B, C] through the block table, then
    attend q over the gathered pages with the same validity mask semantics as
    the contiguous path (k_pos <= pos, optional sliding window). Handles both
    decode (C == 1, per-row positions) and chunked prefill (lane-batched
    [P, C] chunks at per-lane position spans; ``valid`` [B, C] masks padded
    lane positions out of the K/V write — their query rows compute garbage
    that the caller discards). Returns (attn out [B, C, Hq, D],
    (new_k, new_v) block pools).

    ``cfg.use_pallas`` routes single-token decode through the Pallas
    block-table decode kernel and multi-token chunks through the paged
    *prefill* kernel (both in kernels/paged_attention.py — positions of a
    chunk are contiguous per row, which is what the prefill kernel assumes);
    the default path gathers pages and reuses ``mha`` so paged outputs stay
    token-identical to contiguous decode.
    """
    b, c = q.shape[:2]
    pkv = paged_kv_write(pkv, k, v, positions, valid)
    if cfg.use_pallas and c == 1:
        from repro.kernels import ops as kops
        out = kops.paged_attention(q[:, 0], pkv.k, pkv.v, pkv.tables,
                                   positions[:, 0], window)[:, None]
        return out, (pkv.k, pkv.v)
    if cfg.use_pallas and c > 1:
        from repro.kernels import ops as kops
        out = kops.paged_prefill_attention(q, pkv.k, pkv.v, pkv.tables,
                                           positions[:, 0], window)
        return out, (pkv.k, pkv.v)
    kg, vg, k_pos, assigned = paged_kv_gather(pkv)
    kg = shard(kg, "batch", "kv_seq", None, None)
    vg = shard(vg, "batch", "kv_seq", None, None)
    valid = assigned[:, None, :] & (k_pos[:, None, :] <= positions[:, :, None])
    if not (isinstance(window, int) and window == 0):
        valid &= (window == 0) | (k_pos[:, None, :]
                                  > positions[:, :, None] - window)
    out = mha(q, kg, vg, valid[:, None], no_repeat=cfg.gqa_no_repeat,
              scheme=scheme)
    return out, (pkv.k, pkv.v)


def decode_positions(b: int, pos) -> jax.Array:
    """[B, 1] position matrix for a decode step. ``pos`` is a scalar (all
    rows at the same position — static batching, the dry-run's serve step) or
    an int32 [B] vector (per-slot positions — continuous batching)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.full((b, 1), pos, jnp.int32)
    return pos[:, None]


def update_kv_cache(ck, cv, k, v, cache_pos, valid=None):
    """Write one decode step's k/v [B, 1, H, D] into the cache [B, S, H, D]
    at ``cache_pos`` (scalar, or [B] for per-row positions) and return the
    updated cache plus the validity mask over cache positions.

    ``valid`` ([B] bool, per-row positions only) drops rows from the write
    entirely: a frozen row of a multi-step decode horizon (finished budget /
    EOS) must stop writing KV. Masked rows are redirected to an
    out-of-bounds position and scattered with ``mode="drop"``, so the cache
    row is untouched rather than overwritten in place.
    """
    pos = jnp.asarray(cache_pos)
    k_pos = jnp.arange(ck.shape[1])
    if pos.ndim == 0:
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), pos, axis=1)
        return ck, cv, k_pos, pos
    if valid is not None:
        rows = jnp.arange(ck.shape[0])
        pos_eff = jnp.where(valid, pos, ck.shape[1])      # oob -> dropped
        ck = ck.at[rows, pos_eff].set(k[:, 0].astype(ck.dtype), mode="drop")
        cv = cv.at[rows, pos_eff].set(v[:, 0].astype(cv.dtype), mode="drop")
        return ck, cv, k_pos[None, :], pos[:, None]
    upd = lambda c, u, p_: jax.lax.dynamic_update_slice_in_dim(c, u, p_, axis=0)
    ck = jax.vmap(upd)(ck, k.astype(ck.dtype), pos)
    cv = jax.vmap(upd)(cv, v.astype(cv.dtype), pos)
    return ck, cv, k_pos[None, :], pos[:, None]


def attention(p, cfg, x, positions, *, causal: bool = True,
              window: int = 0, kv_cache=None, cache_pos=None,
              cross_kv=None, kv_valid=None):
    """Full attention layer.

    Modes:
      * training / prefill: ``kv_cache is None`` — attend over x itself.
      * decode: ``kv_cache=(k, v)`` with static length S; the current token's
        k/v is written at ``cache_pos`` (scalar, or [B] per-row positions for
        continuous batching) and attention spans the cache.
      * cross attention: ``cross_kv=(k, v)`` precomputed from encoder output.
    ``kv_valid`` masks K/V writes: [B, C] chunk validity for paged prefill
    lanes, or a [B, 1] per-row freeze mask for decode (a finished row of a
    multi-step horizon stops writing KV on both cache backends).
    Returns (out, new_kv_cache_or_None).
    """
    b, s, _ = x.shape
    if isinstance(kv_cache, PagedKV):
        kv_len = kv_cache.tables.shape[1] * kv_cache.k.shape[1]
    else:
        kv_len = (kv_cache[0].shape[1] if kv_cache is not None
                  else cross_kv[0].shape[1] if cross_kv is not None else s)
    scheme = plan_attention_scheme(cfg, b, s, kv_len)
    backend = plan_decode_backend(cfg, kv_cache)
    q, k, v = _qkv(p, cfg, x, scheme=scheme)
    new_cache = None

    if cross_kv is not None:
        k, v = cross_kv
        mask = None
    elif backend == "paged":
        if cfg.pos_emb == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        out, new_cache = paged_decode_attention(cfg, q, k, v, kv_cache,
                                                positions, window, scheme,
                                                valid=kv_valid)
        return out.reshape(b, s, -1) @ p["wo"], new_cache
    elif kv_cache is not None:
        ck, cv = kv_cache
        if cfg.pos_emb == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        ck, cv, k_pos, cpos = update_kv_cache(
            ck, cv, k, v, cache_pos,
            valid=kv_valid[:, 0] if kv_valid is not None else None)
        new_cache = (ck, cv)
        k, v = ck, cv
        valid = k_pos <= cpos
        if window:
            valid &= k_pos > cpos - window
        # [1, Sk] shared-position mask, or [B, 1, 1, Sk] per-row mask
        mask = valid[None, :] if valid.ndim == 1 else valid[:, None, None, :]
        k = shard(k, "batch", "kv_seq", None, None)
        v = shard(v, "batch", "kv_seq", None, None)
    else:
        if cfg.pos_emb == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        q_pos = jnp.arange(s)
        mask = attention_weights_mask(q_pos, q_pos, causal=causal, window=window)
        new_cache = (k, v)          # post-rope k/v, used by prefill to seed a cache

    use_pl = cfg.use_pallas and kv_cache is None and cross_kv is None and causal
    out = mha(q, k, v, None if use_pl else mask, use_pallas=use_pl,
              causal=causal, window=window, no_repeat=cfg.gqa_no_repeat,
              scheme=scheme)
    out = out.reshape(b, s, -1) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init_dense(ks[0], (d, d_ff), dtype),
        "w_up": _init_dense(ks[1], (d, d_ff), dtype),
        "w_down": _init_dense(ks[2], (d_ff, d), dtype),
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "batch", None, "ffn")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------
def init_embeddings(key, cfg, dtype) -> dict:
    ks = jax.random.split(key, 2)
    # Tied embeddings use 1/sqrt(d) init (+ sqrt(d) input scaling, gemma-style)
    # so that tied logits come out unit-scale.
    emb_scale = cfg.d_model ** -0.5 if cfg.tie_embeddings else 1.0
    p = {"tok_emb": _init_dense(ks[0], (cfg.vocab_size, cfg.d_model), dtype,
                                scale=emb_scale)}
    if not cfg.tie_embeddings:
        p["lm_head"] = _init_dense(ks[1], (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed(p, cfg, tokens):
    x = jnp.take(p["tok_emb"], tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)
    return shard(x, "batch", None, None)


def unembed(p, cfg, x):
    if cfg.tie_embeddings:
        logits = x @ p["tok_emb"].T
    else:
        logits = x @ p["lm_head"]
    return shard(logits, "batch", None, "vocab")


def cross_entropy(logits, labels, mask=None):
    """Mean next-token cross entropy in f32. labels: int [B, S]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
