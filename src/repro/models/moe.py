"""Mixture-of-Experts decoder (OLMoE 64e/top-8, Phi-3.5-MoE 16e/top-2).

Token-choice top-k routing with capacity-bounded gather/scatter dispatch:
the dispatch path uses integer gather/scatter (NOT one-hot einsums) so the
compiled HLO FLOPs stay close to the *active* FLOPs — this keeps the roofline
MODEL_FLOPS / HLO_FLOPs ratio honest. Expert FFNs run as a batched GEMM over
the expert axis ([E, C, D] x [E, D, F]) which shards cleanly over the 'model'
mesh axis (expert parallelism; XLA inserts the all-to-all at the sharding
boundary between token-sharded and expert-sharded layouts).

The Pallas ``grouped_matmul`` kernel is the TPU hot-spot implementation of the
same contraction (see repro/kernels/grouped_matmul.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_moe_layer(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "attn": L.init_attention(k1, cfg, dtype),
        "router": L._init_dense(k2, (d, e), dtype),
        "we_gate_up": L._init_dense(k3, (e, d, 2 * f), dtype),
        "we_down": L._init_dense(k4, (e, f, d), dtype),
        "norm1": L.init_rmsnorm(d, dtype),
        "norm2": L.init_rmsnorm(d, dtype),
    }


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        "emb": L.init_embeddings(k_emb, cfg, dtype),
        "layers": jax.vmap(lambda k: init_moe_layer(k, cfg, dtype))(layer_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------
def capacity(cfg, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)          # round up to 8


def moe_ffn(cfg, p, x, *, counts=None, cap_tokens=None, token_valid=None,
            cap_rows=None):
    """x: [B, S, D] -> ([B, S, D], aux_loss[, new_counts]).

    Dispatch is computed independently per batch row (vmap) so the dispatch
    buffers are [B, E, C, D]: batch shards over 'data', experts over 'model'.

    ``counts``/``cap_tokens`` make the layer chunkable (paged prefill):
    ``counts`` [B, E] int32 carries how many assignments each expert has
    already received from earlier chunks of the same sequence — the in-expert
    slot of a token is its global arrival order, so capacity drops land on
    exactly the same tokens as a one-pass forward — and ``cap_tokens`` pins
    the capacity to the full sequence length instead of the chunk length.
    When ``counts`` is given the updated counts are returned as a third
    output.

    ``token_valid``/``cap_rows`` make the layer *lane-batchable* (batched
    prefill): invalid tokens (the padded tail of a short final chunk) claim
    no expert slot, contribute no counts, and combine to zero, and
    ``cap_rows`` [B] int32 pins each lane's *effective* capacity to its own
    prompt's ``capacity(cfg, len)`` while the dispatch buffer is sized by
    the static ``cap_tokens`` bound — so every lane routes exactly like a
    solo one-pass forward over its own prompt.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(cfg, cap_tokens if cap_tokens else s)

    logits = (x @ p["router"]).astype(jnp.float32)               # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                       # [B, S, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)       # renormalize

    # Load-balance auxiliary loss (Switch-style): E * sum(frac_e * mean_prob_e)
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)         # [B, S, K, E]
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))  # [E]
    mean_prob = jnp.mean(probs, axis=(0, 1))                     # [E]
    aux = e * jnp.sum(frac_tokens / k * mean_prob)

    if token_valid is None:
        token_valid = jnp.ones((b, s), bool)
    if cap_rows is None:
        cap_rows = jnp.full((b,), cap, jnp.int32)

    def dispatch_row(xt, row_e, row_p, cnt, tv, cap_row):
        """xt: [S, D]; row_e/row_p: [S, K]; cnt: [E] carried assignment
        counts; tv: [S] token validity; cap_row: scalar effective capacity
        -> ([E, C, D], combine meta, updated counts)."""
        flat_e = row_e.reshape(-1)                               # [S*K]
        flat_p = row_p.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(s), k)
        flat_tv = jnp.repeat(tv, k)
        one = jax.nn.one_hot(flat_e, e, dtype=jnp.int32) * flat_tv[:, None]
        pos_in_e = (cnt[flat_e]
                    + jnp.cumsum(one, axis=0)[jnp.arange(s * k), flat_e] - 1)
        keep = (pos_in_e < cap_row) & flat_tv
        safe_pos = jnp.where(keep, pos_in_e, cap - 1)
        if cfg.moe_gather_dispatch:
            # Scatter only int32 slot->token indices (E*C ints), then gather
            # features locally: avoids XLA's f32 partial-sum all-reduce of
            # the whole [E, C, D] buffer over the expert-sharded axis.
            slot_tok = jnp.full((e, cap), -1, jnp.int32)
            slot_tok = slot_tok.at[flat_e, safe_pos].max(
                jnp.where(keep, flat_tok, -1).astype(jnp.int32))
            buf = jnp.where(slot_tok[..., None] >= 0,
                            jnp.take(xt, jnp.maximum(slot_tok, 0), axis=0),
                            jnp.zeros((), xt.dtype))
        else:
            buf = jnp.zeros((e, cap, d), xt.dtype)
            buf = buf.at[flat_e, safe_pos].add(
                jnp.where(keep[:, None], xt[flat_tok], 0.0))
        return (buf, (flat_e, safe_pos, flat_tok,
                      jnp.where(keep, flat_p, 0.0)),
                cnt + jnp.sum(one, axis=0))

    cnt0 = counts if counts is not None else jnp.zeros((b, e), jnp.int32)
    buf, meta, new_counts = jax.vmap(dispatch_row)(x, top_e, top_p, cnt0,
                                                   token_valid, cap_rows)
    buf = shard(buf, "batch", "experts", None, None)              # [B, E, C, D]

    # expert computation: batched swiglu over the expert axis
    gu = jnp.einsum("becd,edf->becf", buf, p["we_gate_up"])
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("becf,efd->becd", h, p["we_down"])
    out_buf = shard(out_buf, "batch", "experts", None, None)

    def combine_row(out_b, m):
        flat_e, safe_pos, flat_tok, w = m
        y = out_b[flat_e, safe_pos] * w[:, None].astype(out_b.dtype)
        return jax.ops.segment_sum(y, flat_tok, num_segments=s)

    y = jax.vmap(combine_row)(out_buf, meta)                     # [B, S, D]
    if counts is not None:
        return y, aux, new_counts
    return y, aux


# ---------------------------------------------------------------------------
# forward / loss / decode
# ---------------------------------------------------------------------------
def _layer(cfg, p, x, positions, kv_cache=None, cache_pos=None,
           kv_valid=None):
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    attn_out, new_cache = L.attention(p["attn"], cfg, h, positions,
                                      kv_cache=kv_cache, cache_pos=cache_pos,
                                      kv_valid=kv_valid)
    x = x + attn_out
    h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
    ffn_out, aux = moe_ffn(cfg, p, h)
    x = x + ffn_out
    return shard(x, "batch", None, None), new_cache, aux


def forward(cfg, params, tokens, return_aux=False, return_cache=False):
    """tokens: [B, S] int32 -> logits [B, S, V].

    ``return_cache`` captures the per-layer post-rope (k, v) stacks so serving
    can prefill MoE in ONE forward pass (like dense/vlm) instead of the
    O(S)-step decode scan.
    """
    x = L.embed(params["emb"], cfg, tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, p):
        x, aux_sum = carry
        x, kv, aux = _layer(cfg, p, x, positions)
        return (x, aux_sum + aux), kv

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else
                  jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)

    (x, aux_sum), caches = L.scan_layers(cfg, body, (x, jnp.float32(0.0)),
                                         params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    if return_aux and return_cache:
        return logits, aux_sum / cfg.n_layers, caches
    if return_aux:
        return logits, aux_sum / cfg.n_layers
    if return_cache:
        return logits, caches
    return logits


def loss_fn(cfg, params, batch):
    logits, aux = forward(cfg, params, batch["tokens"], return_aux=True)
    ce = L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce + cfg.router_aux_coef * aux


init_cache = T.init_cache
init_paged_cache = T.init_paged_cache


def paged_prefill_state(cfg, batch: int = 1):
    """Per-layer expert assignment counts carried across prefill chunks, so
    capacity drops match the one-pass forward (see moe_ffn)."""
    return jnp.zeros((cfg.n_layers, batch, cfg.n_experts), jnp.int32)


def paged_prefill_chunk(cfg, params, cache, tokens, start, tables,
                        state=None, cap_tokens: int = 0, n_valid=None,
                        cap_rows=None):
    """MoE chunked prefill (lane-batched like the dense path): attention
    pages through each lane's block table; the expert FFN routes with the
    carried per-layer counts, drops lane-padding tokens from dispatch, and
    pins each lane's effective capacity to ``cap_rows`` (its own prompt's
    ``capacity(cfg, len)``; the static ``cap_tokens`` only sizes the
    dispatch buffers) so chunked lane-batched routing equals one-pass
    routing token for token."""
    x = L.embed(params["emb"], cfg, tokens)
    b, c, _ = x.shape
    positions, valid, last = T.prefill_chunk_layout(start, n_valid, b, c)
    if state is None:
        state = paged_prefill_state(cfg, b)

    def body(x, scanned):
        p, ck, cv, cnt = scanned
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        attn_out, new_kv = L.attention(p["attn"], cfg, h, positions,
                                       kv_cache=L.PagedKV(ck, cv, tables),
                                       kv_valid=valid)
        x = x + attn_out
        h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        ffn_out, _aux, new_cnt = moe_ffn(cfg, p, h, counts=cnt,
                                         cap_tokens=cap_tokens,
                                         token_valid=valid,
                                         cap_rows=cap_rows)
        x = shard(x + ffn_out, "batch", None, None)
        return x, (*new_kv, new_cnt)

    x, (new_k, new_v, new_counts) = L.scan_layers(
        cfg, body, x, (params["layers"], cache["k"], cache["v"], state))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    logits = jnp.take_along_axis(logits, last[:, None, None], axis=1)
    return logits, {"k": new_k, "v": new_v}, new_counts


def paged_decode_step(cfg, params, cache, tokens, pos, tables,
                      write_valid=None):
    """One paged decode step (see transformer.paged_decode_step)."""
    x = L.embed(params["emb"], cfg, tokens)
    b = x.shape[0]
    positions = L.decode_positions(b, pos)
    kv_valid = None if write_valid is None else write_valid[:, None]

    def body(x, scanned):
        p, ck, cv = scanned
        x, new_kv, _aux = _layer(cfg, p, x, positions,
                                 kv_cache=L.PagedKV(ck, cv, tables),
                                 kv_valid=kv_valid)
        return x, new_kv

    x, (new_k, new_v) = L.scan_layers(
        cfg, body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    return logits, {"k": new_k, "v": new_v}


def decode_step(cfg, params, cache, tokens, pos, write_valid=None):
    x = L.embed(params["emb"], cfg, tokens)
    b = x.shape[0]
    positions = L.decode_positions(b, pos)
    kv_valid = None if write_valid is None else write_valid[:, None]

    def body(x, scanned):
        p, ck, cv = scanned
        x, new_kv, _aux = _layer(cfg, p, x, positions, kv_cache=(ck, cv),
                                 cache_pos=pos, kv_valid=kv_valid)
        return x, new_kv

    x, (new_k, new_v) = L.scan_layers(cfg, body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    return logits, {"k": new_k, "v": new_v}
