"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
(arXiv:2411.15242).

n_layers counts Mamba2 blocks. A single attention(+MLP) block — one set of
weights — is invoked before every ``shared_attn_every`` Mamba2 blocks. The
structure is compiled as: scan over G = n_layers // every "super-blocks"
(shared attn + `every` scanned mamba blocks), plus a trailing scanned stack
for the remainder. Each shared-attention *invocation* has its own KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import transformer as T


def _split(cfg):
    every = cfg.shared_attn_every
    groups = cfg.n_layers // every if every else 0
    trailing = cfg.n_layers - groups * every
    return every, groups, trailing


# ---------------------------------------------------------------------------
def init_params(cfg, key):
    dtype = jnp.dtype(cfg.param_dtype)
    every, groups, trailing = _split(cfg)
    k_emb, k_shared, k_g, k_t = jax.random.split(key, 4)

    gkeys = jax.random.split(k_g, max(groups * every, 1))[: groups * every]
    tkeys = jax.random.split(k_t, max(trailing, 1))[:trailing]

    params = {
        "emb": L.init_embeddings(k_emb, cfg, dtype),
        "shared": T.init_layer(k_shared, cfg, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if groups:
        stacked = jax.vmap(lambda k: M.init_block(k, cfg, dtype))(gkeys)
        params["groups"] = jax.tree_util.tree_map(
            lambda a: a.reshape(groups, every, *a.shape[1:]), stacked)
    if trailing:
        params["trailing"] = jax.vmap(lambda k: M.init_block(k, cfg, dtype))(tkeys)
    return params


# ---------------------------------------------------------------------------
def _mamba_layer(cfg, p, x):
    h = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    x = x + M.block_fwd(cfg, p, h)
    return shard(x, "batch", None, None), None


def forward(cfg, params, tokens):
    x = L.embed(params["emb"], cfg, tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    every, groups, trailing = _split(cfg)
    w0 = jnp.int32(0)

    def super_block(x, gp):
        # shared attention block (closed-over weights — identical every call)
        x, _ = T._layer(cfg, params["shared"], x, positions, w0)
        # `every` mamba blocks
        x, _ = L.scan_layers(cfg, lambda c, p: _mamba_layer(cfg, p, c), x, gp)
        return x, None

    if cfg.remat != "none":
        super_block = jax.checkpoint(super_block)

    if groups:
        x, _ = L.scan_layers(cfg, super_block, x, params["groups"])
    if trailing:
        x, _ = L.scan_layers(cfg, lambda c, p: _mamba_layer(cfg, p, c), x,
                            params["trailing"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params["emb"], cfg, x)


def loss_fn(cfg, params, batch):
    logits = forward(cfg, params, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    every, groups, trailing = _split(cfg)
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    di, g, n, h, p, conv_ch = M._dims(cfg)
    cache = {
        "attn_k": jnp.zeros((groups, batch, max_len, nkv, hd), dtype),
        "attn_v": jnp.zeros((groups, batch, max_len, nkv, hd), dtype),
        "gconv": jnp.zeros((groups, every, batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "gssm": jnp.zeros((groups, every, batch, h, n, p), jnp.float32),
        "tconv": jnp.zeros((trailing, batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "tssm": jnp.zeros((trailing, batch, h, n, p), jnp.float32),
    }
    return cache


def _mamba_decode(cfg, x, scanned):
    p, conv_s, ssm_s = scanned
    h = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    out, nconv, nssm = M.block_decode(cfg, p, h, conv_s, ssm_s)
    return x + out, (nconv, nssm)


def decode_step(cfg, params, cache, tokens, pos):
    x = L.embed(params["emb"], cfg, tokens)
    b = x.shape[0]
    positions = L.decode_positions(b, pos)
    every, groups, trailing = _split(cfg)
    w0 = jnp.int32(0)

    def super_block(x, scanned):
        gp, ck, cv, gconv, gssm = scanned
        x, new_kv = T._layer(cfg, params["shared"], x, positions, w0,
                             kv_cache=(ck, cv), cache_pos=pos)
        x, (nconv, nssm) = L.scan_layers(
            cfg, lambda c, s: _mamba_decode(cfg, c, s), x, (gp, gconv, gssm))
        return x, (new_kv[0], new_kv[1], nconv, nssm)

    new = dict(cache)
    if groups:
        x, (nk, nv, ngconv, ngssm) = L.scan_layers(
            cfg, super_block, x,
            (params["groups"], cache["attn_k"], cache["attn_v"],
             cache["gconv"], cache["gssm"]))
        new.update(attn_k=nk, attn_v=nv, gconv=ngconv, gssm=ngssm)
    if trailing:
        x, (ntconv, ntssm) = L.scan_layers(
            cfg, lambda c, s: _mamba_decode(cfg, c, s), x,
            (params["trailing"], cache["tconv"], cache["tssm"]))
        new.update(tconv=ntconv, tssm=ntssm)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    return logits, new
