"""Whisper-style encoder-decoder (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is a STUB per the task spec:
``input_specs()`` supplies precomputed frame embeddings [B, enc_seq, D]. The
transformer itself — 32 non-causal encoder layers + 32 decoder layers with
self- and cross-attention — is fully implemented. Positions are sinusoidal
(added to embeddings), matching Whisper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import layers as L


# ---------------------------------------------------------------------------
def init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_attn": L.init_attention(k1, cfg, dtype),
        "cross_attn": L.init_attention(k2, cfg, dtype),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
        "norm1": L.init_rmsnorm(cfg.d_model, dtype),
        "norm2": L.init_rmsnorm(cfg.d_model, dtype),
        "norm3": L.init_rmsnorm(cfg.d_model, dtype),
    }


def init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.init_attention(k1, cfg, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
        "norm1": L.init_rmsnorm(cfg.d_model, dtype),
        "norm2": L.init_rmsnorm(cfg.d_model, dtype),
    }


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "emb": L.init_embeddings(k_emb, cfg, dtype),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg, dtype))(dec_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "dec_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
def encode(cfg, params, frames):
    """frames: [B, enc_seq, D] stub frontend embeddings -> [B, enc_seq, D]."""
    b, s, d = frames.shape
    pe = L.sinusoidal_pos_emb(s, d).astype(frames.dtype)
    x = shard(frames + pe[None], "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, p):
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        a, _ = L.attention(p["attn"], cfg, h, positions, causal=False)
        x = x + a
        h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h)
        return shard(x, "batch", None, None), None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = L.scan_layers(cfg, body, x, params["enc_layers"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(cfg, p, enc_out):
    b, s, _ = enc_out.shape
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    k = (enc_out @ p["wk"]).reshape(b, s, nkv, hd)
    v = (enc_out @ p["wv"]).reshape(b, s, nkv, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(nkv, hd)
        v = v + p["bv"].reshape(nkv, hd)
    return k, v


def _dec_layer(cfg, p, x, positions, enc_out=None, cross_kv=None,
               kv_cache=None, cache_pos=None):
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    a, new_cache = L.attention(p["self_attn"], cfg, h, positions,
                               kv_cache=kv_cache, cache_pos=cache_pos)
    x = x + a
    h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
    if cross_kv is None:
        cross_kv = _cross_kv(cfg, p["cross_attn"], enc_out)
    a, _ = L.attention(p["cross_attn"], cfg, h, positions, cross_kv=cross_kv)
    x = x + a
    h = L.rmsnorm(x, p["norm3"], cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h)
    return shard(x, "batch", None, None), new_cache


def decode_train(cfg, params, tokens, enc_out):
    b, s = tokens.shape
    d = cfg.d_model
    x = L.embed(params["emb"], cfg, tokens)
    pe = L.sinusoidal_pos_emb(s, d).astype(x.dtype)
    x = x + pe[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, p):
        x, _ = _dec_layer(cfg, p, x, positions, enc_out=enc_out)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = L.scan_layers(cfg, body, x, params["dec_layers"])
    x = L.rmsnorm(x, params["dec_norm"], cfg.norm_eps)
    return L.unembed(params["emb"], cfg, x)


def forward(cfg, params, tokens, frames):
    return decode_train(cfg, params, tokens, encode(cfg, params, frames))


def loss_fn(cfg, params, batch):
    logits = forward(cfg, params, batch["tokens"], batch["frames"])
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=None):
    """Self-attn KV cache + precomputed cross K/V (filled at prefill)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    lshape = (cfg.n_layers, batch, max_len, nkv, hd)
    cshape = (cfg.n_layers, batch, cfg.enc_seq, nkv, hd)
    return {"k": jnp.zeros(lshape, dtype), "v": jnp.zeros(lshape, dtype),
            "ck": jnp.zeros(cshape, dtype), "cv": jnp.zeros(cshape, dtype)}


def prefill_cross_kv(cfg, params, frames, cache):
    """Run the encoder and fill the cross-attention K/V stacks."""
    enc_out = encode(cfg, params, frames)

    def body(_, p):
        return None, _cross_kv(cfg, p["cross_attn"], enc_out)

    _, (ck, cv) = L.scan_layers(cfg, body, None, params["dec_layers"])
    cache = dict(cache)
    cache["ck"], cache["cv"] = ck.astype(cache["ck"].dtype), cv.astype(cache["cv"].dtype)
    return cache


def decode_step(cfg, params, cache, tokens, pos):
    b = tokens.shape[0]
    x = L.embed(params["emb"], cfg, tokens)
    pe = L.sinusoidal_pos_emb(cache["k"].shape[2], cfg.d_model).astype(x.dtype)
    positions = L.decode_positions(b, pos)
    if jnp.asarray(pos).ndim == 0:
        x = x + jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None]
    else:
        x = x + jnp.take(pe, positions[:, 0], axis=0)[:, None, :]

    def body(x, scanned):
        p, ck_, cv_, xk, xv = scanned
        x, new_kv = _dec_layer(cfg, p, x, positions, cross_kv=(xk, xv),
                               kv_cache=(ck_, cv_), cache_pos=pos)
        return x, new_kv

    x, (nk, nv) = L.scan_layers(
        cfg, body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    x = L.rmsnorm(x, params["dec_norm"], cfg.norm_eps)
    logits = L.unembed(params["emb"], cfg, x)
    return logits, {"k": nk, "v": nv, "ck": cache["ck"], "cv": cache["cv"]}
