"""Checkpointing: msgpack-serialized pytrees (lease termination, §4.3).

When the scheduler terminates a job's lease, the Synergy iterator checkpoints
the train state to shared storage; on re-placement training resumes exactly.
No orbax dependency — arrays go through raw bytes + dtype/shape headers.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x):
    arr = np.asarray(x)
    return {b"__nd__": True, b"dtype": arr.dtype.str, b"shape": list(arr.shape),
            b"data": arr.tobytes()}


def _unpack_leaf(d):
    arr = np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"]))
    return jnp.asarray(arr.reshape(d[b"shape"]))


def save(path: str, tree: Any) -> None:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        b"treedef": str(treedef).encode(),
        b"leaves": [_pack_leaf(l) for l in leaves],
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write: tmp + rename (a killed lease must never corrupt the ckpt)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload))
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    leaves, treedef = jax.tree_util.tree_flatten(like)
    saved = [_unpack_leaf(d) for d in payload[b"leaves"]]
    if len(saved) != len(leaves):
        raise ValueError(f"checkpoint has {len(saved)} leaves, expected {len(leaves)}")
    for s, l in zip(saved, leaves):
        if s.shape != l.shape:
            raise ValueError(f"shape mismatch: {s.shape} vs {l.shape}")
    return jax.tree_util.tree_unflatten(treedef, saved)


def exists(path: str) -> bool:
    return os.path.exists(path)
