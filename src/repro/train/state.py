"""TrainState: a plain pytree bundling params + optimizer state + step."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer, apply_updates


def create(params, optimizer: Optimizer) -> Dict[str, Any]:
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(loss_fn, optimizer: Optimizer):
    """(state, batch) -> (state, metrics). Pure function — jit/pjit it."""

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        updates, opt, gnorm = optimizer.update(
            grads, state["opt"], state["params"], state["step"])
        new_state = {
            "params": apply_updates(state["params"], updates),
            "opt": opt,
            "step": state["step"] + 1,
        }
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
