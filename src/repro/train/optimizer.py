"""Optimizers + LR schedules (pure JAX, no external deps).

AdamW with decoupled weight decay and global-norm gradient clipping, plus
SGD-momentum; warmup-cosine and warmup-linear schedules. Optimizer state is a
plain pytree so it shards exactly like the parameters.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def warmup_linear(peak_lr: float, warmup_steps: int, total_steps: int) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        lin = peak_lr * jnp.clip(1.0 - t, 0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, lin)
    return schedule


def constant(lr: float) -> Callable:
    return lambda step: jnp.float32(lr)


# ---------------------------------------------------------------------------
# grad clipping
# ---------------------------------------------------------------------------
def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Optimizer:
    init: Callable         # params -> opt_state
    update: Callable       # (grads, opt_state, params, step) -> (updates, opt_state)
    name: str = "opt"


def adamw(schedule: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"mu": jax.tree_util.tree_map(zeros, params),
                "nu": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, mu, nu, p):
            g32 = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * jnp.square(g32)
            step_ = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
            step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (-lr * step_).astype(p.dtype), mu, nu

        out = jax.tree_util.tree_map(upd, grads, state["mu"], state["nu"], params)
        updates = jax.tree_util.tree_map(lambda o: o[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda o: o[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda o: o[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu, "nu": nu}, gnorm

    return Optimizer(init=init, update=update, name="adamw")


def sgdm(schedule: Callable, momentum: float = 0.9,
         clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        return {"mom": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        lr = schedule(step)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (-lr * m).astype(p.dtype), m

        out = jax.tree_util.tree_map(upd, grads, state["mom"], params)
        updates = jax.tree_util.tree_map(lambda o: o[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mom = jax.tree_util.tree_map(lambda o: o[1], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mom": mom}, gnorm

    return Optimizer(init=init, update=update, name="sgdm")


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
