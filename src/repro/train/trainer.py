"""Training loop over the unified model API.

Accepts any batch iterator — in particular the Synergy iterator
(repro.core.iterator), which is how the scheduler's CPU/memory leases reach
the data pipeline. Works on one CPU device and under pjit on a mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.api import build_model
from repro.train import checkpoint as ckpt_lib
from repro.train import state as state_lib
from repro.train.optimizer import adamw, warmup_cosine


@dataclass
class TrainerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 20
    total_steps: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    log_every: int = 10
    ckpt_path: Optional[str] = None
    ckpt_every: int = 0


# Memoize jitted step functions: many Trainer instances for the same config
# (live profiling probes, restarted leases) must share one compiled step.
_STEP_FN_CACHE: Dict = {}


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig = TrainerConfig(),
                 rng=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.model = build_model(cfg)
        self.optimizer = adamw(
            warmup_cosine(tcfg.peak_lr, tcfg.warmup_steps, tcfg.total_steps),
            weight_decay=tcfg.weight_decay, clip_norm=tcfg.clip_norm)
        rng = rng if rng is not None else jax.random.key(0)
        params = self.model.init(rng)
        self.state = state_lib.create(params, self.optimizer)
        key = (cfg, tcfg.peak_lr, tcfg.warmup_steps, tcfg.total_steps,
               tcfg.weight_decay, tcfg.clip_norm)
        if key not in _STEP_FN_CACHE:
            _STEP_FN_CACHE[key] = jax.jit(
                state_lib.make_train_step(self.model.loss, self.optimizer))
        self._step_fn = _STEP_FN_CACHE[key]
        self.history: List[Dict[str, float]] = []

    @property
    def step(self) -> int:
        return int(self.state["step"])

    def maybe_restore(self) -> bool:
        p = self.tcfg.ckpt_path
        if p and ckpt_lib.exists(p):
            self.state = ckpt_lib.restore(p, self.state)
            return True
        return False

    def save(self) -> None:
        if self.tcfg.ckpt_path:
            ckpt_lib.save(self.tcfg.ckpt_path, self.state)

    def train_step(self, batch) -> Dict[str, float]:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        self.state, metrics = self._step_fn(self.state, batch)
        loss = float(metrics["loss"])
        rec = {"step": self.step, "loss": loss,
               "grad_norm": float(metrics["grad_norm"]),
               "step_seconds": time.perf_counter() - t0}
        self.history.append(rec)
        return rec

    def fit(self, batches: Iterable[dict],
            max_steps: Optional[int] = None) -> List[Dict[str, float]]:
        n = 0
        for batch in batches:
            rec = self.train_step(batch)
            n += 1
            if (self.tcfg.ckpt_every and self.tcfg.ckpt_path
                    and n % self.tcfg.ckpt_every == 0):
                self.save()
            if max_steps is not None and n >= max_steps:
                break
        return self.history
