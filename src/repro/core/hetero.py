"""Heterogeneous GPU clusters (paper Appendix A.2).

The paper's homogeneous LP extends with a machine-type dimension: each job
carries a per-type sensitivity matrix W_ij[c, m] (the 3-D matrix of §6), the
variables become y_{c,m,i,j} (job j gets c CPU / m mem on super-machine type
i — a job never splits across types within a round), and the fairness floor
compares against an oracle fair throughput W_j^Fair (eqs. 22–26).

This module implements that ILP plus the paper's "improving utilization"
loop: re-solve over leftover capacity and the next wait-queue slice until no
GPUs or jobs remain.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np
from scipy import optimize, sparse

from repro.core.job import Job
from repro.core.cluster import ServerSpec
from repro.core.sensitivity import MODEL_ZOO, SensitivityMatrix, WorkloadModel, throughput


@dataclass(frozen=True)
class MachineType:
    name: str
    n_machines: int
    spec: ServerSpec
    gpu_speed: float = 1.0          # relative accelerator generation speed


def hetero_matrix(model: WorkloadModel, gpus: int, mtype: MachineType,
                  cpu_points, mem_points, min_mem_gb: float = 20.0
                  ) -> SensitivityMatrix:
    """W_ij: the per-type sensitivity matrix — t_gpu scales with the
    generation speed, CPU/memory behaviour is unchanged."""
    scaled = WorkloadModel(
        name=model.name, task=model.task, batch_per_gpu=model.batch_per_gpu,
        t_gpu=model.t_gpu / mtype.gpu_speed, k_cpu=model.k_cpu,
        sample_mb=model.sample_mb, dataset_gb=model.dataset_gb,
        disk_bw_mbps=model.disk_bw_mbps)
    cpu_points = np.asarray(sorted(cpu_points), float)
    mem_points = np.asarray(sorted(mem_points), float)
    W = np.zeros((len(cpu_points), len(mem_points)))
    for ci, c in enumerate(cpu_points):
        for mi, m in enumerate(mem_points):
            W[ci, mi] = throughput(scaled, gpus, c, m, min_mem_gb=min_mem_gb)
    return SensitivityMatrix(cpu_points, mem_points, W, gpus)


@dataclass
class HeteroResult:
    alloc: Dict[int, Tuple[str, float, float]]      # job -> (type, c*, m*)
    throughput: float
    fair_throughput: float
    solve_seconds: float
    unplaced: List[int] = field(default_factory=list)


def solve_hetero(jobs: Sequence[Job], types: Sequence[MachineType],
                 *, mem_unit: float = 50.0, time_limit: float = 30.0,
                 fair_oracle: Dict[int, float] = None) -> HeteroResult:
    """ILP (22)–(26): one (c, m, type) per job; per-type CPU/mem/GPU caps;
    throughput >= W_j^Fair."""
    t0 = time.perf_counter()
    mats: Dict[Tuple[int, str], SensitivityMatrix] = {}
    for job in jobs:
        model = MODEL_ZOO[job.model_name]
        for t in types:
            cpu_pts = np.arange(1.0, t.spec.cpus + 1.0)
            mem_pts = np.arange(mem_unit, t.spec.mem + 1e-9, mem_unit)
            mats[(job.job_id, t.name)] = hetero_matrix(
                model, job.gpu_demand, t, cpu_pts, mem_pts)

    # fair oracle: proportional share on the SLOWEST type (a conservative,
    # heterogeneity-aware floor — the paper defers to an external scheduler)
    if fair_oracle is None:
        slowest = min(types, key=lambda t: t.gpu_speed)
        fair_oracle = {}
        for job in jobs:
            m = mats[(job.job_id, slowest.name)]
            cg = job.gpu_demand * slowest.spec.cpu_per_gpu
            mg = job.gpu_demand * slowest.spec.mem_per_gpu
            fair_oracle[job.job_id] = m.rate(cg, mg)

    # variables: pareto options per (job, type)
    opts: List[Tuple[int, int, float, float, float]] = []  # (ji, ti, c, m, w)
    job_slices: List[Tuple[int, int]] = []
    from repro.core.opt import pareto_options

    for ji, job in enumerate(jobs):
        lo = len(opts)
        for ti, t in enumerate(types):
            mat = mats[(job.job_id, t.name)]
            tmp = Job(job_id=-1, model_name=job.model_name,
                      gpu_demand=job.gpu_demand, arrival_time=0, duration=1)
            tmp.matrix = mat
            for c, m, w in pareto_options(tmp):
                opts.append((ji, ti, c, m, w))
        job_slices.append((lo, len(opts)))

    nv = len(opts)
    n, k = len(jobs), len(types)
    wvec = np.array([o[4] for o in opts])
    rows, cols, vals, b_lo, b_hi = [], [], [], [], []
    r = 0
    for ti, t in enumerate(types):        # per-type CPU/mem/GPU caps (23,24)
        caps = (t.spec.cpus * t.n_machines, t.spec.mem * t.n_machines,
                t.spec.gpus * t.n_machines)
        for dim, cap in enumerate(caps):
            for vi, (ji, ti2, c, m, w) in enumerate(opts):
                if ti2 != ti:
                    continue
                val = (c, m, jobs[ji].gpu_demand)[dim]
                rows.append(r)
                cols.append(vi)
                vals.append(val)
            b_lo.append(-np.inf)
            b_hi.append(cap)
            r += 1
    for ji, (lo, hi) in enumerate(job_slices):     # one config (25)
        rows += [r] * (hi - lo)
        cols += list(range(lo, hi))
        vals += [1.0] * (hi - lo)
        b_lo.append(1.0)
        b_hi.append(1.0)
        r += 1
    for ji, (lo, hi) in enumerate(job_slices):     # fairness (26)
        rows += [r] * (hi - lo)
        cols += list(range(lo, hi))
        vals += list(wvec[lo:hi])
        b_lo.append(fair_oracle[jobs[ji].job_id])
        b_hi.append(np.inf)
        r += 1

    A = sparse.csr_matrix((vals, (rows, cols)), shape=(r, nv))
    res = optimize.milp(
        c=-wvec,
        constraints=optimize.LinearConstraint(A, np.array(b_lo), np.array(b_hi)),
        bounds=optimize.Bounds(0.0, 1.0),
        integrality=np.ones(nv),
        options={"time_limit": time_limit})

    dt = time.perf_counter() - t0
    if res.x is None:
        return HeteroResult({}, 0.0, sum(fair_oracle.values()), dt,
                            unplaced=[j.job_id for j in jobs])
    alloc = {}
    for ji, (lo, hi) in enumerate(job_slices):
        best = lo + int(np.argmax(res.x[lo:hi]))
        _, ti, c, m, w = opts[best]
        alloc[jobs[ji].job_id] = (types[ti].name, c, m)
    return HeteroResult(alloc, float(-res.fun),
                        float(sum(fair_oracle.values())), dt)
