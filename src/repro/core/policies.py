"""Scheduling policies (§2.2, §5.1): FIFO, SRTF, LAS, FTF (+ DRF for §5.7).

A policy only ORDERS the queue; Synergy's mechanism (allocators.py) decides
placement and auxiliary-resource amounts. This separation is the paper's
point: Synergy augments any policy.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.core.job import Job


class Policy:
    name = "policy"

    def priority(self, job: Job, now: float) -> float:
        raise NotImplementedError

    def order(self, jobs: Sequence[Job], now: float) -> List[Job]:
        return sorted(jobs, key=lambda j: (self.priority(j, now), j.arrival_time,
                                           j.job_id))


class FIFO(Policy):
    name = "fifo"

    def priority(self, job: Job, now: float) -> float:
        return job.arrival_time


class SRTF(Policy):
    """Shortest Remaining Time First (remaining GPU-proportional work)."""
    name = "srtf"

    def priority(self, job: Job, now: float) -> float:
        return job.remaining


class LAS(Policy):
    """Least Attained Service (Tiresias-style; GPU-seconds attained)."""
    name = "las"

    def priority(self, job: Job, now: float) -> float:
        return job.attained_service


class FTF(Policy):
    """Finish-Time Fairness (Themis-style).

    rho = T_projected / T_ideal: projected completion (elapsed + remaining at
    proportional rate) over the job's ideal isolated runtime. Jobs with the
    largest rho (most unfairly treated) go first -> sort by -rho.
    """
    name = "ftf"

    def priority(self, job: Job, now: float) -> float:
        elapsed = now - job.arrival_time
        projected = elapsed + job.remaining
        ideal = max(job.duration, 1e-9)
        rho = projected / ideal
        return -rho


class DRF(Policy):
    """Dominant Resource Fairness (§5.7): smallest dominant share first.

    The dominant share uses the job's *static* demand vector (DRF assumes
    demands are fixed — precisely what Synergy relaxes).
    """
    name = "drf"

    def __init__(self, total_gpus: float, total_cpus: float, total_mem: float):
        self.totals = (total_gpus, total_cpus, total_mem)

    def priority(self, job: Job, now: float) -> float:
        g, c, m = job.gpu_demand, job.demand_cpu, job.demand_mem
        shares = (g / self.totals[0], c / self.totals[1], m / self.totals[2])
        # attained-weighted: DRF grants the next task to the user with the
        # least dominant share attained; approximate with service-weighted share
        return max(shares) * (1.0 + job.attained_service / 3600.0)


POLICIES = {p.name: p for p in (FIFO(), SRTF(), LAS(), FTF())}


def get_policy(name: str, cluster=None) -> Policy:
    if name == "drf":
        assert cluster is not None
        return DRF(cluster.total_gpus, cluster.total_cpus, cluster.total_mem)
    return {"fifo": FIFO, "srtf": SRTF, "las": LAS, "ftf": FTF}[name]()
