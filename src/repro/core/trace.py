"""Workload traces (§5.1).

Production-derived traces: GPU demand distribution from the public Philly
trace analysis [33] (heavily skewed to 1-GPU jobs; multi-GPU up to 16);
durations 10^x minutes with x ~ U[1.5,3] w.p. 0.8 else U[3,4] (as in [44]);
arrivals either static (all at t=0, makespan experiments) or Poisson at a
configurable load (jobs/hr). A workload *split* (image%, language%, speech%)
assigns each job a model from the paper's zoo (Table 4).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.core.job import Job
from repro.core.sensitivity import MODEL_ZOO

# Empirical GPU-demand mix from the Philly trace characterization [33]
PHILLY_GPU_MIX: Sequence[Tuple[int, float]] = (
    (1, 0.70), (2, 0.10), (4, 0.10), (8, 0.05), (16, 0.05),
)

_BY_TASK = {
    "image": [m for m in MODEL_ZOO.values() if m.task == "image"],
    "language": [m for m in MODEL_ZOO.values() if m.task == "language"],
    "speech": [m for m in MODEL_ZOO.values() if m.task == "speech"],
}


@dataclass
class TraceConfig:
    n_jobs: int = 1000
    split: Tuple[int, int, int] = (20, 70, 10)       # image, language, speech %
    arrival: str = "poisson"                          # poisson | static
    jobs_per_hour: float = 8.0
    multi_gpu: bool = True                            # False -> all 1-GPU
    max_gpus_per_job: int = 16
    seed: int = 0
    duration_scale: float = 1.0


def _sample_duration(rng: random.Random) -> float:
    """Paper §5.1: 10^x minutes; x~U[1.5,3] w.p. .8, else U[3,4]."""
    if rng.random() < 0.8:
        x = rng.uniform(1.5, 3.0)
    else:
        x = rng.uniform(3.0, 4.0)
    return (10.0 ** x) * 60.0          # seconds


def _sample_gpus(rng: random.Random, cfg: TraceConfig) -> int:
    if not cfg.multi_gpu:
        return 1
    r = rng.random()
    acc = 0.0
    for g, p in PHILLY_GPU_MIX:
        acc += p
        if r <= acc and g <= cfg.max_gpus_per_job:
            return g
    return 1


def _sample_model(rng: random.Random, cfg: TraceConfig) -> str:
    r = rng.random() * 100.0
    im, la, sp = cfg.split
    if r < im:
        task = "image"
    elif r < im + la:
        task = "language"
    else:
        task = "speech"
    return rng.choice(_BY_TASK[task]).name


def generate(cfg: TraceConfig) -> List[Job]:
    rng = random.Random(cfg.seed)
    jobs: List[Job] = []
    t = 0.0
    for i in range(cfg.n_jobs):
        if cfg.arrival == "poisson":
            t += rng.expovariate(cfg.jobs_per_hour / 3600.0)
            arrival = t
        else:
            arrival = 0.0
        jobs.append(Job(
            job_id=i,
            model_name=_sample_model(rng, cfg),
            gpu_demand=_sample_gpus(rng, cfg),
            arrival_time=arrival,
            duration=_sample_duration(rng) * cfg.duration_scale,
        ))
    return jobs


def philly_trace(n_jobs: int = 8000, split=(20, 70, 10), seed: int = 7,
                 jobs_per_hour: float = 64.0) -> List[Job]:
    """Philly-like subrange (§5.3.1): preserves the published GPU-demand and
    duration distributions with continuous arrivals at production load."""
    return generate(TraceConfig(n_jobs=n_jobs, split=split, arrival="poisson",
                                jobs_per_hour=jobs_per_hour, multi_gpu=True,
                                seed=seed))
