"""Resource-sensitivity model: the physics behind W_j[c, m] (§2, §3.1).

Per-step time on ``g`` accelerators is the max of three service times
(compute, CPU preprocessing, storage fetch) — the data-stall decomposition of
[41] that the paper builds on:

    t_gpu              accelerator step time (model-specific)
    t_prep(c)  = g*b*k_cpu / c            k_cpu: CPU-seconds per sample
    t_fetch(m) = g*b*(1-h(m))*s_mb / bw   h(m): MinIO cache hit rate = m/D

MinIO guarantees a *fixed* hit rate h = min(1, m / dataset_gb) per epoch,
which makes t_fetch linear and predictable in m — the property that licenses
optimistic profiling (empirical probes only along c at m = m_max).

``MODEL_ZOO`` carries the paper's ten workload models with constants
calibrated to Figure 2 (CPU cores/GPU needed to saturate) and the §2.1 memory
experiments (ResNet18 2x from 62->500 GB; GNMT flat). The assigned
architecture families map onto the same three sensitivity classes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class WorkloadModel:
    """Constants for one DNN workload (per single accelerator)."""
    name: str
    task: str                # image | language | speech
    batch_per_gpu: int       # samples per accelerator per step
    t_gpu: float             # seconds per step (compute-bound floor)
    k_cpu: float             # CPU-seconds of preprocessing per sample
    sample_mb: float         # bytes fetched per sample (MB)
    dataset_gb: float        # full dataset size (GB) -> MinIO hit rate
    disk_bw_mbps: float = 500.0   # storage bandwidth per job (MB/s)

    def cpus_to_saturate(self) -> float:
        return self.batch_per_gpu * self.k_cpu / self.t_gpu


def _image(name, sat_cpus, t_gpu=0.20, b=128, sample_mb=0.12, dataset_gb=550):
    # k_cpu chosen so that t_prep(c=sat_cpus) == t_gpu  (Fig. 2 calibration)
    return WorkloadModel(name, "image", b, t_gpu, sat_cpus * t_gpu / b,
                         sample_mb, dataset_gb)


def _speech(name, sat_cpus, t_gpu=0.25, b=32, sample_mb=0.5, dataset_gb=700):
    return WorkloadModel(name, "speech", b, t_gpu, sat_cpus * t_gpu / b,
                         sample_mb, dataset_gb)


def _lang(name, sat_cpus=1.0, t_gpu=0.30, b=64, sample_mb=0.02, dataset_gb=15):
    return WorkloadModel(name, "language", b, t_gpu, sat_cpus * t_gpu / b,
                         sample_mb, dataset_gb)


# Paper Table 4 models; saturation points read off Figure 2a.
MODEL_ZOO: Dict[str, WorkloadModel] = {m.name: m for m in [
    _image("shufflenetv2", 12.0, t_gpu=0.10),
    _image("alexnet", 12.0, t_gpu=0.12),
    _image("resnet18", 9.0, t_gpu=0.17),
    _image("mobilenetv2", 9.0, t_gpu=0.18),
    _image("resnet50", 6.0, t_gpu=0.35),
    _lang("gnmt", 1.0, t_gpu=0.55),
    _lang("lstm", 1.0, t_gpu=0.20),
    _lang("transformer-xl", 1.0, t_gpu=0.40),
    _speech("m5", 8.0, t_gpu=0.22),
    _speech("deepspeech", 5.0, t_gpu=0.60),
]}

TASK_OF = {name: m.task for name, m in MODEL_ZOO.items()}

# Assigned-architecture -> workload-class mapping (DESIGN.md §5): the live
# runtime schedules jobs whose models are the assigned archs; their Synergy
# sensitivity class reuses the calibrated zoo constants.
ARCH_SENSITIVITY = {
    "whisper-large-v3": "deepspeech",
    "phi-3-vision-4.2b": "resnet18",
    "olmoe-1b-7b": "transformer-xl",
    "llama3.2-1b": "lstm",
    "phi3.5-moe-42b-a6.6b": "gnmt",
    "qwen2-0.5b": "lstm",
    "zamba2-7b": "gnmt",
    "qwen2-7b": "gnmt",
    "mamba2-780m": "transformer-xl",
    "gemma3-27b": "gnmt",
}


# ---------------------------------------------------------------------------
# throughput model
# ---------------------------------------------------------------------------
def throughput(model: WorkloadModel, gpus: int, cpus: float, mem_gb: float,
               *, min_mem_gb: float = 20.0) -> float:
    """Steady-state samples/sec for a job with (gpus, cpus, mem_gb).

    mem below ``min_mem_gb`` (process working set) is infeasible -> 0.
    """
    if gpus <= 0 or cpus <= 0 or mem_gb < min_mem_gb:
        return 0.0
    b = model.batch_per_gpu * gpus
    t_prep = b * model.k_cpu / cpus
    cache_gb = max(mem_gb - min_mem_gb, 0.0)
    hit = min(1.0, cache_gb / model.dataset_gb)
    t_fetch = b * (1.0 - hit) * model.sample_mb / model.disk_bw_mbps
    step = max(model.t_gpu, t_prep, t_fetch)
    return b / step


# ---------------------------------------------------------------------------
# sensitivity matrix
# ---------------------------------------------------------------------------
@dataclass
class SensitivityMatrix:
    """W[c, m]: job progress rate over discrete (CPU, mem) allocations."""
    cpu_points: np.ndarray         # [NC] candidate CPU allocations (job total)
    mem_points: np.ndarray         # [NM] candidate memory allocations (GB)
    W: np.ndarray                  # [NC, NM] samples/sec
    gpus: int
    profile_probes: int = 0        # empirical probes spent (§3.1 accounting)
    profile_seconds: float = 0.0

    def rate(self, cpus: float, mem: float) -> float:
        """Throughput at an arbitrary (c, m) — floor-indexed into the grid."""
        ci = int(np.searchsorted(self.cpu_points, cpus + 1e-9) - 1)
        mi = int(np.searchsorted(self.mem_points, mem + 1e-9) - 1)
        ci = max(0, min(ci, len(self.cpu_points) - 1))
        mi = max(0, min(mi, len(self.mem_points) - 1))
        return float(self.W[ci, mi])

    def max_rate(self) -> float:
        return float(self.W.max())

    def best_demand(self, knee: float = 0.95,
                    floor_rate: float = 0.0) -> Tuple[float, float]:
        """Minimum (c, m) reaching ``knee`` of max throughput (demand vector).

        ``floor_rate`` (the GPU-proportional throughput) guarantees the
        demand vector never asks for less than proportional *throughput* —
        the paper's fairness requirement (§4.2).
        """
        target = max(self.max_rate() * knee, min(floor_rate, self.max_rate()))
        best = (float(self.cpu_points[-1]), float(self.mem_points[-1]))
        best_cost = math.inf
        for ci, c in enumerate(self.cpu_points):
            for mi, m in enumerate(self.mem_points):
                if self.W[ci, mi] >= target:
                    # lexicographic-ish cost: CPUs are scarcer than memory
                    cost = c / self.cpu_points[-1] + 0.5 * m / self.mem_points[-1]
                    if cost < best_cost:
                        best_cost, best = cost, (float(c), float(m))
        return best

    def curve(self, mem: float):
        """1-D rate curve along the CPU axis at a fixed ``mem`` — the shape
        ``opt.greedy_allocate`` consumes (the serve-side tenant allocator
        splits its block pool over these)."""
        return lambda c: self.rate(c, mem)

    def best_second_axis(self, cpus: float, knee: float = 0.95) -> float:
        """Minimum mem-axis point reaching ``knee`` of the best rate
        available at a fixed ``cpus`` — the per-axis knee (the serve
        profiler reads the horizon-K knee at a tenant's block budget)."""
        ci = int(np.searchsorted(self.cpu_points, cpus + 1e-9) - 1)
        ci = max(0, min(ci, len(self.cpu_points) - 1))
        row = self.W[ci]
        target = float(row.max()) * knee
        for mi, m in enumerate(self.mem_points):
            if row[mi] >= target:
                return float(m)
        return float(self.mem_points[-1])

    def options(self) -> List[Tuple[float, float, float]]:
        """All (c, m, W) triples — the discrete space of the OPT ILP (§4.1)."""
        out = []
        for ci, c in enumerate(self.cpu_points):
            for mi, m in enumerate(self.mem_points):
                out.append((float(c), float(m), float(self.W[ci, mi])))
        return out


def full_matrix(model: WorkloadModel, gpus: int,
                cpu_points: Sequence[float], mem_points: Sequence[float],
                min_mem_gb: float = 20.0) -> SensitivityMatrix:
    """Ground-truth matrix (what exhaustive profiling would measure)."""
    cpu_points = np.asarray(sorted(cpu_points), float)
    mem_points = np.asarray(sorted(mem_points), float)
    W = np.zeros((len(cpu_points), len(mem_points)))
    for ci, c in enumerate(cpu_points):
        for mi, m in enumerate(mem_points):
            W[ci, mi] = throughput(model, gpus, c, m, min_mem_gb=min_mem_gb)
    return SensitivityMatrix(cpu_points, mem_points, W, gpus)
