"""Event-driven cluster simulator (§4.3).

A global event queue carries job arrivals, round-boundary schedule events and
job finishes. On arrival a job is profiled (optimistic profiler) and enqueued.
At each schedule event the policy orders the queue, all leases are recomputed
and the mechanism re-packs the runnable set (lease renewal is implicit: a job
keeps running iff it is re-placed). Between rounds jobs advance at the rate
given by their sensitivity matrix at the allocated (c, m); finishes release
resources immediately (reused at the next round).

Fidelity knobs match the paper: 5-minute rounds, profiling overhead
accounting, steady-state measurement windows.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.allocators import Allocator, get_allocator
from repro.core.cluster import Cluster, ServerSpec
from repro.core.job import Job
from repro.core.policies import Policy, get_policy
from repro.core.profiler import OptimisticProfiler, ProfilerConfig


@dataclass
class SimConfig:
    round_seconds: float = 300.0
    policy: str = "srtf"
    allocator: str = "tune"
    include_profile_overhead: bool = False
    steady_skip: int = 0              # ignore the first N finished jobs
    steady_count: int = 0             # 0 = measure all jobs
    max_hours: float = 24_000.0
    opt_time_limit: float = 30.0      # Synergy-OPT per-round ILP budget


@dataclass
class SimResult:
    jobs: List[Job]
    avg_jct: float
    p99_jct: float
    makespan: float
    util_samples: List[Dict[str, float]] = field(default_factory=list)
    util_times: List[float] = field(default_factory=list)
    queue_len_samples: List[int] = field(default_factory=list)
    rounds: int = 0
    opt_solve_seconds: float = 0.0

    def monitored(self, skip: int, count: int) -> List[Job]:
        done = [j for j in self.jobs if j.finish_time is not None]
        done.sort(key=lambda j: j.arrival_time)
        if count:
            return done[skip:skip + count]
        return done[skip:]


class _OptAllocator(Allocator):
    """Synergy-OPT as a round mechanism: ILP for (c,m), TUNE-style placement."""
    name = "opt"

    def __init__(self, time_limit: float = 30.0):
        from repro.core.allocators import SynergyTune
        self._tune = SynergyTune()
        self.time_limit = time_limit
        self.total_solve_seconds = 0.0

    def schedule(self, cluster: Cluster, queue: Sequence[Job]):
        from repro.core import opt as opt_mod
        from repro.core.allocators import RoundPlan, try_place

        # runnable set exactly like TUNE (GPUs first)
        runnable, skipped = [], []
        free = cluster.free_gpus
        for job in queue:
            if job.gpu_demand <= free:
                runnable.append(job)
                free -= job.gpu_demand
            else:
                skipped.append(job.job_id)
        if not runnable:
            return self._finish(cluster, queue, RoundPlan(skipped=skipped))

        res = opt_mod.solve_ideal(runnable, cluster, integer=True,
                                  time_limit=self.time_limit)
        self.total_solve_seconds += res.solve_seconds
        if not res.alloc:               # infeasible -> fall back to TUNE
            return self._tune.schedule(cluster, queue)

        plan = RoundPlan(skipped=skipped)
        order = sorted(runnable, key=lambda j: (-j.gpu_demand,))
        for job in order:
            c, m = res.alloc[job.job_id]
            if try_place(cluster, job, c, m):
                plan.scheduled[job.job_id] = (c, m)
            else:
                # materialization fallback (§4.1.3): demote via TUNE chain
                self._tune._place_with_fallback(cluster, job, plan)
        return self._finish(cluster, queue, plan)


def _make_allocator(name: str, cfg: SimConfig) -> Allocator:
    if name == "opt":
        return _OptAllocator(cfg.opt_time_limit)
    return get_allocator(name)


class Simulator:
    def __init__(self, cluster: Cluster, jobs: Sequence[Job], cfg: SimConfig,
                 profiler: Optional[OptimisticProfiler] = None,
                 policy: Optional[Policy] = None,
                 allocator: Optional[Allocator] = None):
        self.cluster = cluster
        self.jobs = sorted(jobs, key=lambda j: j.arrival_time)
        self.cfg = cfg
        self.profiler = profiler or OptimisticProfiler(cluster.spec)
        self.policy = policy or get_policy(cfg.policy, cluster)
        self.allocator = allocator or _make_allocator(cfg.allocator, cfg)

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.cfg
        t = 0.0
        next_arrival_idx = 0
        queue: List[Job] = []
        finished: List[Job] = []
        result = SimResult(jobs=list(self.jobs), avg_jct=0, p99_jct=0, makespan=0)
        n = len(self.jobs)
        max_t = cfg.max_hours * 3600.0
        dirty = True                     # re-schedule only when the mix changed

        pending: List = []               # (ready_time, job_id, job) min-heap

        while len(finished) < n and t < max_t:
            # admit arrivals; with overhead accounting a job only becomes
            # schedulable after its empirical probes finish (§5: JCT is still
            # measured from arrival, so profiling time is charged to the job)
            while (next_arrival_idx < n
                   and self.jobs[next_arrival_idx].arrival_time <= t + 1e-9):
                job = self.jobs[next_arrival_idx]
                self.profiler.profile_job(job)
                next_arrival_idx += 1
                if cfg.include_profile_overhead and job.matrix is not None:
                    job.profile_overhead_s = job.matrix.profile_seconds
                ready = job.arrival_time + job.profile_overhead_s
                if ready <= t + 1e-9:
                    queue.append(job)
                    dirty = True
                else:
                    heapq.heappush(pending, (ready, job.job_id, job))
            while pending and pending[0][0] <= t + 1e-9:
                queue.append(heapq.heappop(pending)[2])
                dirty = True

            # schedule round
            if dirty or self.policy.name in ("las", "ftf"):
                self.cluster.release_all()
                ordered = self.policy.order(queue, t)
                plan = self.allocator.schedule(self.cluster, ordered)
                for job in queue:
                    if job.current_rate > 0 and job.start_time is None:
                        job.start_time = t
                result.rounds += 1
                dirty = False
            util = self.cluster.utilization()
            result.util_samples.append(util)
            result.util_times.append(t)
            result.queue_len_samples.append(
                sum(1 for j in queue if j.current_rate == 0) + len(pending))

            # advance to next round boundary, processing finishes inside
            round_end = t + cfg.round_seconds
            if next_arrival_idx < n:
                round_end = min(round_end,
                                max(t + 1.0, self.jobs[next_arrival_idx].arrival_time))
            if pending:
                round_end = min(round_end, max(t + 1.0, pending[0][0]))
            while t < round_end - 1e-9:
                running = [j for j in queue if j.current_rate > 0]
                ttf = min((j.time_to_finish() for j in running),
                          default=float("inf"))
                dt = min(round_end - t, ttf)
                if dt <= 0:
                    dt = 1e-6
                for j in running:
                    j.advance(dt)
                t += dt
                done_now = [j for j in running if j.finished]
                for j in done_now:
                    j.finish_time = t
                    j.current_rate = 0.0
                    self.cluster.release_job(j.job_id)
                    queue.remove(j)
                    finished.append(j)
                    dirty = True
                if not running:
                    # idle: jump to the next arrival or profile completion
                    upcoming = []
                    if next_arrival_idx < n:
                        upcoming.append(self.jobs[next_arrival_idx].arrival_time)
                    if pending:
                        upcoming.append(pending[0][0])
                    if upcoming:
                        t = max(t, min(upcoming))
                    break
            if not queue and not pending and next_arrival_idx >= n:
                break

        mon = [j for j in finished]
        if cfg.steady_count:
            mon.sort(key=lambda j: j.arrival_time)
            mon = mon[cfg.steady_skip:cfg.steady_skip + cfg.steady_count]
        jcts = np.array([j.jct() for j in mon if j.jct() is not None])
        result.avg_jct = float(jcts.mean()) if len(jcts) else float("nan")
        result.p99_jct = float(np.percentile(jcts, 99)) if len(jcts) else float("nan")
        result.makespan = max((j.finish_time or 0.0) for j in finished) if finished else 0.0
        if isinstance(self.allocator, _OptAllocator):
            result.opt_solve_seconds = self.allocator.total_solve_seconds
        return result


def simulate(n_servers: int, jobs: Sequence[Job], *, policy: str = "srtf",
             allocator: str = "tune", round_seconds: float = 300.0,
             spec: ServerSpec = ServerSpec(), steady_skip: int = 0,
             steady_count: int = 0, max_hours: float = 24_000.0,
             include_profile_overhead: bool = False) -> SimResult:
    cfg = SimConfig(round_seconds=round_seconds, policy=policy,
                    allocator=allocator, steady_skip=steady_skip,
                    steady_count=steady_count, max_hours=max_hours,
                    include_profile_overhead=include_profile_overhead)
    sim = Simulator(Cluster(n_servers, spec), jobs, cfg)
    return sim.run()
