"""Cluster resource model: homogeneous servers with (GPU, CPU, mem) vectors.

Matches the paper's experimental server: 8 accelerators, 24 CPU cores, 500 GB
DRAM (§5.1) — i.e. CPU:GPU ratio 3, GPU-proportional memory 62.5 GB/GPU. The
ratio is configurable for the Fig. 12 sweep.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class Allocation:
    """Resources a job holds on ONE server."""
    job_id: int
    gpus: int
    cpus: float
    mem: float


@dataclass
class ServerSpec:
    gpus: int = 8
    cpus: float = 24.0
    mem: float = 500.0        # GB

    @property
    def cpu_per_gpu(self) -> float:
        return self.cpus / self.gpus

    @property
    def mem_per_gpu(self) -> float:
        return self.mem / self.gpus


@dataclass
class Server:
    sid: int
    spec: ServerSpec
    allocs: Dict[int, Allocation] = field(default_factory=dict)

    # -- free resources ------------------------------------------------------
    @property
    def free_gpus(self) -> int:
        return self.spec.gpus - sum(a.gpus for a in self.allocs.values())

    @property
    def free_cpus(self) -> float:
        return self.spec.cpus - sum(a.cpus for a in self.allocs.values())

    @property
    def free_mem(self) -> float:
        return self.spec.mem - sum(a.mem for a in self.allocs.values())

    def fits(self, gpus: int, cpus: float, mem: float, eps: float = 1e-9) -> bool:
        return (self.free_gpus >= gpus and self.free_cpus >= cpus - eps
                and self.free_mem >= mem - eps)

    def allocate(self, job_id: int, gpus: int, cpus: float, mem: float) -> None:
        if not self.fits(gpus, cpus, mem):
            raise ValueError(
                f"server {self.sid}: cannot fit ({gpus},{cpus},{mem}); free="
                f"({self.free_gpus},{self.free_cpus:.1f},{self.free_mem:.1f})")
        if job_id in self.allocs:
            a = self.allocs[job_id]
            a.gpus += gpus
            a.cpus += cpus
            a.mem += mem
        else:
            self.allocs[job_id] = Allocation(job_id, gpus, cpus, mem)

    def release(self, job_id: int) -> Optional[Allocation]:
        return self.allocs.pop(job_id, None)


class Cluster:
    """A homogeneous cluster of servers."""

    def __init__(self, n_servers: int, spec: ServerSpec = ServerSpec()):
        self.spec = spec
        self.servers: List[Server] = [Server(i, spec) for i in range(n_servers)]

    # -- capacity ------------------------------------------------------------
    @property
    def total_gpus(self) -> int:
        return self.spec.gpus * len(self.servers)

    @property
    def total_cpus(self) -> float:
        return self.spec.cpus * len(self.servers)

    @property
    def total_mem(self) -> float:
        return self.spec.mem * len(self.servers)

    @property
    def free_gpus(self) -> int:
        return sum(s.free_gpus for s in self.servers)

    @property
    def free_cpus(self) -> float:
        return sum(s.free_cpus for s in self.servers)

    @property
    def free_mem(self) -> float:
        return sum(s.free_mem for s in self.servers)

    # -- GPU-proportional shares (§2) -----------------------------------------
    def proportional_demand(self, gpus: int) -> Tuple[float, float]:
        return gpus * self.spec.cpu_per_gpu, gpus * self.spec.mem_per_gpu

    # -- job placement bookkeeping --------------------------------------------
    def placement_of(self, job_id: int) -> List[Tuple[int, Allocation]]:
        return [(s.sid, s.allocs[job_id]) for s in self.servers
                if job_id in s.allocs]

    def release_job(self, job_id: int) -> None:
        for s in self.servers:
            s.release(job_id)

    def release_all(self) -> None:
        for s in self.servers:
            s.allocs.clear()

    def job_totals(self, job_id: int) -> Tuple[int, float, float]:
        g = c = m = 0.0
        for _, a in self.placement_of(job_id):
            g += a.gpus
            c += a.cpus
            m += a.mem
        return int(g), c, m

    def utilization(self) -> Dict[str, float]:
        return {
            "gpu": 1.0 - self.free_gpus / self.total_gpus,
            "cpu": 1.0 - self.free_cpus / self.total_cpus,
            "mem": 1.0 - self.free_mem / self.total_mem,
        }

    def running_job_ids(self) -> Sequence[int]:
        ids = set()
        for s in self.servers:
            ids.update(s.allocs)
        return sorted(ids)
