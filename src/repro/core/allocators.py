"""Scheduling mechanisms (§3.2–§4.2).

All mechanisms receive (a) an empty cluster (round-based rescheduling: every
round the full placement is recomputed, jobs renew leases) and (b) the queue
in policy order. They write allocations into the cluster and set each
scheduled job's ``current_rate`` from its sensitivity matrix.

 * ``GPUProportional`` — the ubiquitous baseline (§2).
 * ``SynergyGreedy``   — first-fit with best-case demands; SKIPS jobs that do
                         not fit (fragmentation + unfairness, §3.3).
 * ``SynergyTune``     — the paper's contribution (§4.2): never skips a job
                         whose GPU demand fits; reverts over-proportional
                         demands, and demotes over-proportional *victims* to
                         their fair share to make room. Guarantees every
                         scheduled job >= GPU-proportional throughput.
 * ``StaticBestFit``   — static multi-dim packing for the DRF/Tetris
                         comparison (§5.7): demands fixed, no tuning.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cluster import Cluster, Server
from repro.core.job import Job
from repro.core.sensitivity import MODEL_ZOO


@dataclass
class RoundPlan:
    """Outcome of one scheduling round."""
    scheduled: Dict[int, Tuple[float, float]] = field(default_factory=dict)
    skipped: List[int] = field(default_factory=list)
    demoted: List[int] = field(default_factory=list)

    def rate_of(self, job: Job) -> float:
        if job.job_id not in self.scheduled:
            return 0.0
        c, m = self.scheduled[job.job_id]
        return job.matrix.rate(c, m)


# ---------------------------------------------------------------------------
# placement helpers
# ---------------------------------------------------------------------------
def _best_fit_single(cluster: Cluster, g: int, c: float, m: float
                     ) -> Optional[Server]:
    """Server with the least free resources that still fits (g, c, m)."""
    cands = [s for s in cluster.servers if s.fits(g, c, m)]
    if not cands:
        return None
    return min(cands, key=lambda s: (s.free_gpus, s.free_cpus, s.free_mem))


def _split_proportional(g: int, c: float, m: float,
                        shares: Sequence[int]) -> List[Tuple[int, float, float]]:
    """CPU/mem proportional to the per-server GPU share (§4.2 requirement)."""
    return [(gi, c * gi / g, m * gi / g) for gi in shares]


def _min_server_set(cluster: Cluster, g: int, *, by_gpu_only: bool,
                    c: float = 0.0, m: float = 0.0
                    ) -> Optional[List[Tuple[Server, int]]]:
    """Minimum set of servers (by free GPUs desc) covering ``g`` GPUs.

    When ``by_gpu_only`` is False, each chosen server must also fit its
    proportional CPU/mem share.
    """
    avail = [s for s in cluster.servers if s.free_gpus > 0]
    # best-fit when one server suffices: fewest free GPUs that still fit
    single = sorted((s for s in avail if s.free_gpus >= g),
                    key=lambda s: (s.free_gpus, s.free_cpus, s.free_mem))
    for s in single:
        if by_gpu_only or s.fits(g, c, m):
            return [(s, g)]
    servers = sorted(avail, key=lambda s: -s.free_gpus)
    chosen: List[Tuple[Server, int]] = []
    left = g
    for s in servers:
        take = min(s.free_gpus, left)
        if take <= 0:
            continue
        if not by_gpu_only:
            if not s.fits(take, c * take / g, m * take / g):
                continue
        chosen.append((s, take))
        left -= take
        if left == 0:
            return chosen
    return None


def try_place(cluster: Cluster, job: Job, c: float, m: float) -> bool:
    """Place ``job`` with auxiliary demand (c, m); single-GPU jobs (and any
    job that fits) are consolidated on one server, larger jobs split with
    proportional shares."""
    g = job.gpu_demand
    if g <= cluster.spec.gpus:
        s = _best_fit_single(cluster, g, c, m)
        if s is not None:
            s.allocate(job.job_id, g, c, m)
            return True
        if g <= 1:
            return False
    chosen = _min_server_set(cluster, g, by_gpu_only=False, c=c, m=m)
    if chosen is None:
        return False
    for s, gi in chosen:
        s.allocate(job.job_id, gi, c * gi / g, m * gi / g)
    return True


# ---------------------------------------------------------------------------
# allocators
# ---------------------------------------------------------------------------
class Allocator:
    name = "allocator"

    def schedule(self, cluster: Cluster, queue: Sequence[Job]) -> RoundPlan:
        raise NotImplementedError

    # shared: record outcome + set job rates
    def _finish(self, cluster: Cluster, queue: Sequence[Job],
                plan: RoundPlan) -> RoundPlan:
        for job in queue:
            if job.job_id in plan.scheduled:
                c, m = plan.scheduled[job.job_id]
                job.current_rate = job.matrix.rate(c, m)
            else:
                job.current_rate = 0.0
        return plan


class GPUProportional(Allocator):
    name = "proportional"

    def schedule(self, cluster: Cluster, queue: Sequence[Job]) -> RoundPlan:
        plan = RoundPlan()
        for job in queue:
            g = job.gpu_demand
            if g > cluster.free_gpus:
                plan.skipped.append(job.job_id)
                continue
            c, m = cluster.proportional_demand(g)
            if try_place(cluster, job, c, m):
                plan.scheduled[job.job_id] = (c, m)
            else:
                plan.skipped.append(job.job_id)
        return self._finish(cluster, queue, plan)


class SynergyGreedy(Allocator):
    """First-fit with best-case demands; skips non-fitting jobs (§3.3)."""
    name = "greedy"

    def schedule(self, cluster: Cluster, queue: Sequence[Job]) -> RoundPlan:
        plan = RoundPlan()
        for job in queue:
            if job.gpu_demand > cluster.free_gpus:
                plan.skipped.append(job.job_id)
                continue
            if try_place(cluster, job, job.demand_cpu, job.demand_mem):
                plan.scheduled[job.job_id] = (job.demand_cpu, job.demand_mem)
            else:
                plan.skipped.append(job.job_id)     # the fatal skip
        return self._finish(cluster, queue, plan)


class StaticBestFit(Allocator):
    """DRF/Tetris-style static multi-dimensional packing (§5.7): demands are
    fixed inputs; no reversion/demotion.

    ``blocking=True`` models DRF's share-ordered offers: resources go to the
    lowest-dominant-share job first, and a job that does not fit BLOCKS the
    queue (head-of-line) — which is what fragments GPUs at resource-heavy
    splits in the paper's Fig. 13. Tetris instead re-sorts by its packing
    alignment score each placement and skips."""
    name = "static"

    def __init__(self, tetris_order: bool = False, blocking: bool = True):
        self.tetris_order = tetris_order
        self.blocking = blocking and not tetris_order
        if tetris_order:
            self.name = "tetris"

    def schedule(self, cluster: Cluster, queue: Sequence[Job]) -> RoundPlan:
        plan = RoundPlan()
        pending = list(queue)
        while pending:
            if self.tetris_order:
                # Tetris: pick the job with max alignment(demand, free)
                def score(j):
                    return (j.gpu_demand * cluster.free_gpus
                            + j.demand_cpu * cluster.free_cpus
                            + (j.demand_mem * cluster.free_mem) / 100.0)
                pending.sort(key=score, reverse=True)
            job = pending.pop(0)
            if (job.gpu_demand <= cluster.free_gpus
                    and try_place(cluster, job, job.demand_cpu, job.demand_mem)):
                plan.scheduled[job.job_id] = (job.demand_cpu, job.demand_mem)
            else:
                plan.skipped.append(job.job_id)
                if self.blocking:
                    plan.skipped.extend(j.job_id for j in pending)
                    break
        return self._finish(cluster, queue, plan)


class SynergyTune(Allocator):
    """The paper's near-optimal heuristic (§4.2)."""
    name = "tune"

    def schedule(self, cluster: Cluster, queue: Sequence[Job]) -> RoundPlan:
        plan = RoundPlan()

        # 1. runnable set: top jobs whose GPU demand can be exactly satisfied,
        #    irrespective of fungible demands. Never skip a job that fits by
        #    GPUs -> no GPU under-utilization at full load.
        runnable: List[Job] = []
        free = cluster.free_gpus
        for job in queue:
            if job.gpu_demand <= free:
                runnable.append(job)
                free -= job.gpu_demand
            else:
                plan.skipped.append(job.job_id)

        # 2. pack hardest-to-place first: GPU, then CPU, then memory demand.
        order = sorted(runnable, key=lambda j: (-j.gpu_demand, -j.demand_cpu,
                                                -j.demand_mem))
        by_id = {j.job_id: j for j in runnable}
        for job in order:
            self._place_with_fallback(cluster, job, plan)

        # 3. redistribute leftovers (§5.3.2): per server, hand unallocated CPU
        #    and memory to the resident job with the highest marginal gain.
        self._redistribute(cluster, by_id, plan)
        return self._finish(cluster, queue, plan)

    def _redistribute(self, cluster: Cluster, by_id: Dict[int, Job],
                      plan: RoundPlan, mem_step: float = 25.0) -> None:
        for s in cluster.servers:
            # only single-server residents: multi-server jobs require
            # GPU-proportional shares on every server (§4.2), which a local
            # bump would break.
            local = [a for a in s.allocs.values()
                     if len(cluster.placement_of(a.job_id)) == 1
                     and a.job_id in by_id]
            while True:
                best_gain, best_apply = 0.0, None
                for a in local:
                    job = by_id[a.job_id]
                    base = job.matrix.rate(a.cpus, a.mem)
                    if s.free_cpus >= 1.0:
                        gain = job.matrix.rate(a.cpus + 1.0, a.mem) - base
                        if gain > best_gain * (1 + 1e-12):
                            best_gain, best_apply = gain, (a, 1.0, 0.0)
                    if s.free_mem >= mem_step:
                        gain = job.matrix.rate(a.cpus, a.mem + mem_step) - base
                        if gain > best_gain * (1 + 1e-12):
                            best_gain, best_apply = gain, (a, 0.0, mem_step)
                if best_apply is None or best_gain <= 1e-12:
                    break
                a, dc, dm = best_apply
                a.cpus += dc
                a.mem += dm
                plan.scheduled[a.job_id] = cluster.job_totals(a.job_id)[1:]

    # -- the §4.2 fallback chain ------------------------------------------------
    def _place_with_fallback(self, cluster: Cluster, job: Job,
                             plan: RoundPlan) -> None:
        g = job.gpu_demand
        c, m = job.demand_cpu, job.demand_mem
        cg, mg = cluster.proportional_demand(g)

        if try_place(cluster, job, c, m):
            plan.scheduled[job.job_id] = (c, m)
            return

        # (1) demand above proportional -> revert to proportional and retry
        if c > cg + 1e-9 or m > mg + 1e-9:
            c, m = min(c, cg), min(m, mg)
            if try_place(cluster, job, c, m):
                plan.scheduled[job.job_id] = (c, m)
                return

        # (2) place by GPUs only; demote over-proportional victims on those
        #     servers to fair share until the job fits.
        chosen = _min_server_set(cluster, g, by_gpu_only=True)
        if chosen is None:         # cannot happen for runnable set, by GPUs
            plan.skipped.append(job.job_id)
            return
        for s, gi in chosen:
            need_c, need_m = c * gi / g, m * gi / g
            self._demote_until_fits(cluster, s, gi, need_c, need_m, plan)
            # after demotion the fair-share invariant guarantees fit at <= prop
            s.allocate(job.job_id, gi, min(need_c, s.free_cpus),
                       min(need_m, s.free_mem))
        plan.scheduled[job.job_id] = cluster.job_totals(job.job_id)[1:]

    def _demote_until_fits(self, cluster: Cluster, s: Server, gi: int,
                           need_c: float, need_m: float,
                           plan: RoundPlan) -> None:
        """Switch over-proportional jobs on server ``s`` to fair share, largest
        excess first, until (gi, need_c, need_m) fits."""
        spec = cluster.spec
        if s.free_gpus < gi:
            return                 # GPU deficit cannot be fixed by demotion
        while not s.fits(gi, need_c, need_m):
            # a victim is over-proportional in a dimension the server is
            # short on; score by excess in the deficit dimension(s) only
            short_c = s.free_cpus < need_c - 1e-9
            short_m = s.free_mem < need_m - 1e-9
            victims = []
            for a in s.allocs.values():
                exc_c = a.cpus - a.gpus * spec.cpu_per_gpu
                exc_m = a.mem - a.gpus * spec.mem_per_gpu
                score = ((exc_c / spec.cpus if short_c else 0.0)
                         + (exc_m / spec.mem if short_m else 0.0))
                if score > 1e-9:
                    victims.append((score, a))
            if not victims:
                break              # nothing left to demote
            victims.sort(key=lambda t: -t[0])
            _, a = victims[0]
            a.cpus = min(a.cpus, a.gpus * spec.cpu_per_gpu)
            a.mem = min(a.mem, a.gpus * spec.mem_per_gpu)
            plan.demoted.append(a.job_id)
            if a.job_id in plan.scheduled:
                plan.scheduled[a.job_id] = cluster.job_totals(a.job_id)[1:]


class SynergyTuneSplit(SynergyTune):
    """Beyond-paper: the consolidation-vs-allocation tradeoff the paper
    leaves to future work (§6).

    A multi-GPU job that *could* consolidate on one server may instead be
    split across servers when the extra CPU/memory it can then claim raises
    its throughput by more than the network-split penalty. The penalty is a
    multiplicative throughput tax (default 10%, cf. the consolidation
    penalties measured by [43, 58]).
    """
    name = "tune_split"

    def __init__(self, split_penalty: float = 0.10):
        self.split_penalty = split_penalty

    def _place_with_fallback(self, cluster: Cluster, job: Job,
                             plan: RoundPlan) -> None:
        g = job.gpu_demand
        if 1 < g <= cluster.spec.gpus:
            # candidate A: consolidated placement at whatever (c, m) fits
            servers = [s for s in cluster.servers if s.free_gpus >= g]
            best_single = None
            for s in servers:
                c = min(job.demand_cpu, s.free_cpus)
                m = min(job.demand_mem, s.free_mem)
                r = job.matrix.rate(c, m)
                if best_single is None or r > best_single[0]:
                    best_single = (r, s, c, m)
            # candidate B: split across the 2 freest servers, proportional
            chosen = _min_server_set(cluster, g, by_gpu_only=False,
                                     c=job.demand_cpu, m=job.demand_mem)
            if chosen and len(chosen) > 1 and best_single is not None:
                split_rate = (job.matrix.rate(job.demand_cpu, job.demand_mem)
                              * (1.0 - self.split_penalty))
                if split_rate > best_single[0] + 1e-9:
                    for s, gi in chosen:
                        s.allocate(job.job_id, gi,
                                   job.demand_cpu * gi / g,
                                   job.demand_mem * gi / g)
                    plan.scheduled[job.job_id] = (job.demand_cpu,
                                                  job.demand_mem)
                    return
        super()._place_with_fallback(cluster, job, plan)

    def _finish(self, cluster, queue, plan):
        plan = super()._finish(cluster, queue, plan)
        # apply the split penalty to the achieved rates
        for job in queue:
            if (job.job_id in plan.scheduled
                    and len(cluster.placement_of(job.job_id)) > 1
                    and job.gpu_demand <= cluster.spec.gpus):
                job.current_rate *= (1.0 - self.split_penalty)
        return plan


ALLOCATORS = {
    "proportional": GPUProportional,
    "greedy": SynergyGreedy,
    "tune": SynergyTune,
    "tune_split": SynergyTuneSplit,
    "static": StaticBestFit,
}


def get_allocator(name: str) -> Allocator:
    if name == "tetris":
        return StaticBestFit(tetris_order=True)
    return ALLOCATORS[name]()
