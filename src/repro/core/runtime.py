"""Live round-based runtime: real JAX training jobs under Synergy control.

This is the reduced-scale analogue of the paper's 32-GPU physical cluster
(Table 5). Jobs are threads running REAL train steps of the assigned
architectures (reduced configs) through REAL data pipelines; the scheduler's
round loop recomputes placements with the same policies/mechanisms as the
simulator and pushes CPU-worker / MinIO-capacity leases to each job's
Synergy iterator. Job throughputs are *measured* from progress reports —
nothing in the deploy column comes from the analytic model.

Scale/honesty notes (DESIGN.md §9): accelerator slots are virtual (one host
CPU device executes all jobs); preprocessing parallelism uses the pipeline's
'scaled' mode because the container has a single physical core. Absolute
step times are therefore distorted equally across mechanisms; the JCT/
makespan *ratios* are the fidelity check.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_config
from repro.core.allocators import get_allocator
from repro.core.cluster import Cluster, ServerSpec
from repro.core.iterator import ControlChannel, SynergyIterator
from repro.core.job import Job
from repro.core.policies import get_policy
from repro.core.profiler import OptimisticProfiler, ProfilerConfig
from repro.core.sensitivity import ARCH_SENSITIVITY, MODEL_ZOO, WorkloadModel
from repro.data.pipeline import DataConfig, DataPipeline
from repro.train.trainer import Trainer, TrainerConfig


@dataclass
class LiveJobSpec:
    job_id: int
    arch_id: str
    total_iters: int = 40
    batch_size: int = 8
    gpu_demand: int = 1
    preprocess_cost_s: float = 0.002
    dataset_gb: float = 2.0
    sample_mb: float = 1.0
    seq_len: int = 32
    arrival_time: float = 0.0


class LiveJob:
    def __init__(self, spec: LiveJobSpec, ckpt_dir: str):
        self.spec = spec
        self.channel = ControlChannel(spec.job_id)
        n_samples = int(spec.dataset_gb * 1024 / spec.sample_mb)
        self.data_cfg = DataConfig(
            n_samples=n_samples, seq_len=spec.seq_len,
            vocab_size=get_config(spec.arch_id, smoke=True).vocab_size,
            preprocess_cost_s=spec.preprocess_cost_s,
            sample_bytes=int(spec.sample_mb * (1 << 20)),
            simulate_io=False, parallel_mode="scaled", seed=spec.job_id)
        self.ckpt_path = os.path.join(ckpt_dir, f"job{spec.job_id}.ckpt")
        self.pipeline: Optional[DataPipeline] = None
        self.trainer: Optional[Trainer] = None
        self.thread: Optional[threading.Thread] = None
        self.iters_done = 0
        self.running = False
        self.done = threading.Event()
        self.sched_job: Optional[Job] = None   # core Job seen by the allocator
        self.progress_log: List = []           # (t, iters)
        self.submit_time: Optional[float] = None
        self.finish_wall: Optional[float] = None

    # -- training thread -----------------------------------------------------
    def _make_trainer(self) -> Trainer:
        cfg = get_config(self.spec.arch_id, smoke=True)
        tcfg = TrainerConfig(total_steps=self.spec.total_iters,
                             ckpt_path=self.ckpt_path, warmup_steps=2)
        tr = Trainer(cfg, tcfg)
        tr.maybe_restore()
        return tr

    def _adapt_batch(self, cfg, batch: dict, step: int) -> dict:
        """Add the stub modality-frontend embeddings (DESIGN.md carve-out)."""
        b = batch["tokens"].shape[0]
        rng = np.random.default_rng(step)
        if cfg.family == "encdec":
            batch = dict(batch)
            batch["frames"] = rng.standard_normal(
                (b, cfg.enc_seq, cfg.d_model)).astype(np.float32) * 0.02
        elif cfg.family == "vlm":
            batch = dict(batch)
            batch["patch_embeds"] = rng.standard_normal(
                (b, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
        return batch

    def start(self, cpus: float, mem_gb: float) -> None:
        assert not self.running
        self.pipeline = DataPipeline(self.data_cfg, self.spec.batch_size,
                                     n_workers=max(1, int(round(cpus))))
        self.pipeline.set_cache_gb(mem_gb)
        self.running = True

        def main():
            trainer = self._make_trainer()
            self.trainer = trainer
            self.iters_done = int(trainer.step)
            it = SynergyIterator(self.spec.job_id, self.pipeline, self.channel,
                                 on_terminate=trainer.save)
            for batch in it:
                batch = self._adapt_batch(trainer.cfg, batch, self.iters_done)
                trainer.train_step(batch)
                self.iters_done = int(trainer.step)
                self.progress_log.append((time.time(), self.iters_done))
                if self.iters_done >= self.spec.total_iters:
                    self.finish_wall = time.time()
                    self.done.set()
                    break
            self.running = False
            self.pipeline.close()

        self.thread = threading.Thread(target=main, daemon=True)
        self.thread.start()

    def stop(self) -> None:
        """Terminate the lease: checkpoint + stop the thread."""
        if self.running:
            self.channel.terminate()
            self.thread.join(timeout=30.0)
            self.running = False
            if self.sched_job is not None:
                self.sched_job.n_preemptions += 1

    def update_lease(self, cpus: float, mem_gb: float) -> None:
        self.channel.send_lease(cpus, mem_gb)


class LiveRuntime:
    def __init__(self, n_servers: int = 2,
                 spec: ServerSpec = ServerSpec(gpus=2, cpus=6.0, mem=4.0),
                 policy: str = "srtf", allocator: str = "tune",
                 round_seconds: float = 2.0, probe_iters: int = 2,
                 ckpt_dir: Optional[str] = None):
        self.cluster = Cluster(n_servers, spec)
        self.policy = get_policy(policy, self.cluster)
        self.allocator = get_allocator(allocator)
        self.round_seconds = round_seconds
        self.probe_iters = probe_iters
        self.ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="synergy_ckpt_")
        self.profiler = OptimisticProfiler(
            spec, ProfilerConfig(mem_unit_gb=1.0, min_mem_gb=0.0))
        self.jobs: Dict[int, LiveJob] = {}
        self.round_log: List[Dict] = []

    # -- live optimistic profiling ------------------------------------------------
    def _measure_rate(self, lj: LiveJob, cpus: float) -> float:
        """Actually run a few train steps at this CPU allocation, full cache."""
        pipeline = DataPipeline(lj.data_cfg, lj.spec.batch_size,
                                n_workers=max(1, int(round(cpus))))
        pipeline.set_cache_gb(lj.data_cfg.n_samples * lj.data_cfg.sample_bytes
                              / (1 << 30) + 1.0)
        trainer = lj._make_trainer()
        gen = pipeline.batches(self.probe_iters + 1)
        trainer.train_step(lj._adapt_batch(trainer.cfg, next(gen), 0))  # warmup
        t0 = time.perf_counter()
        n = 0
        for batch in gen:
            trainer.train_step(lj._adapt_batch(trainer.cfg, batch, n))
            n += 1
        dt = time.perf_counter() - t0
        pipeline.close()
        return n * lj.spec.batch_size / max(dt, 1e-9)

    def _profile(self, lj: LiveJob) -> None:
        spec = lj.spec
        wm = WorkloadModel(
            name=spec.arch_id, task=MODEL_ZOO[ARCH_SENSITIVITY[spec.arch_id]].task,
            batch_per_gpu=spec.batch_size, t_gpu=1.0, k_cpu=0.0,
            sample_mb=spec.sample_mb, dataset_gb=spec.dataset_gb,
            disk_bw_mbps=lj.data_cfg.disk_bw_bytes / 1e6)
        mat = self.profiler.profile(wm, spec.gpu_demand,
                                    measure_fn=lambda c: self._measure_rate(lj, c))
        j = Job(job_id=spec.job_id, model_name=ARCH_SENSITIVITY[spec.arch_id],
                gpu_demand=spec.gpu_demand, arrival_time=spec.arrival_time,
                duration=spec.total_iters, arch_id=spec.arch_id)
        j.matrix = mat
        cg, mg = self.cluster.proportional_demand(spec.gpu_demand)
        j.prop_rate = mat.rate(cg, mg)
        j.demand_cpu, j.demand_mem = mat.best_demand(floor_rate=j.prop_rate)
        lj.sched_job = j

    # -- public API -------------------------------------------------------------
    def submit(self, spec: LiveJobSpec) -> None:
        lj = LiveJob(spec, self.ckpt_dir)
        lj.submit_time = time.time()
        self._profile(lj)
        self.jobs[spec.job_id] = lj

    def run(self, max_rounds: int = 100) -> Dict:
        t_start = time.time()
        for rnd in range(max_rounds):
            active = {jid: lj for jid, lj in self.jobs.items()
                      if not lj.done.is_set()}
            if not active:
                break
            queue = [lj.sched_job for lj in active.values()]
            # remaining work for SRTF: iters left at measured base rate
            for lj in active.values():
                lj.sched_job.remaining = max(
                    1e-9, lj.spec.total_iters - lj.iters_done)
            self.cluster.release_all()
            ordered = self.policy.order(queue, time.time() - t_start)
            plan = self.allocator.schedule(self.cluster, ordered)

            for jid, lj in active.items():
                if jid in plan.scheduled:
                    c, m = plan.scheduled[jid]
                    if not lj.running:
                        lj.start(c, m)
                    else:
                        lj.update_lease(c, m)
                elif lj.running:
                    lj.stop()

            self.round_log.append({
                "round": rnd,
                "t": time.time() - t_start,
                "scheduled": sorted(plan.scheduled),
                "util": self.cluster.utilization(),
            })
            deadline = time.time() + self.round_seconds
            while time.time() < deadline:
                if all(lj.done.is_set() for lj in active.values()):
                    break
                time.sleep(0.05)

        # drain: stop any stragglers
        for lj in self.jobs.values():
            if lj.running:
                lj.stop()
        return self.metrics(t_start)

    def metrics(self, t_start: float) -> Dict:
        jcts = []
        for lj in self.jobs.values():
            if lj.finish_wall is not None:
                jcts.append(lj.finish_wall - t_start - lj.spec.arrival_time)
        makespan = max((lj.finish_wall or time.time()) for lj in
                       self.jobs.values()) - t_start if self.jobs else 0.0
        return {
            "avg_jct": float(np.mean(jcts)) if jcts else float("nan"),
            "p99_jct": float(np.percentile(jcts, 99)) if jcts else float("nan"),
            "makespan": makespan,
            "finished": len(jcts),
            "total": len(self.jobs),
        }
