"""Optimistic profiling (§3.1).

Naive profiling cost: |CPU points| x |mem points| probes (~4 hours for a
24-CPU/500GB server at a minute each). Synergy instead:

 1. Empirically probes throughput only along the CPU axis at FULL memory
    (so t_fetch == 0), choosing probe points by the paper's binary search:
    probe the midpoint; if the improvement from mid -> hi is below a
    threshold the knee lies below, so recurse into the lower half, else into
    the upper half. ~log2(24)+2 ~ 8 probes instead of 24.
 2. Analytically fills the rest of the matrix: with a MinIO cache the hit
    rate at memory m is fixed and known (h = cache/dataset), so
    t_fetch(m) is predictable and  W[c, m] = b / max(b / W_emp(c), t_fetch(m)).

``measure_fn`` abstracts "run the job for ~50 iterations": the simulator
passes the analytic ground truth (optionally + noise); the live runtime
passes a closure that actually executes train steps with a bounded CPU pool.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core.cluster import ServerSpec
from repro.core.sensitivity import (MODEL_ZOO, SensitivityMatrix,
                                    WorkloadModel, throughput)


@dataclass(frozen=True)
class ProfilerConfig:
    improvement_threshold: float = 0.10   # paper's 10% binary-search threshold
    knee: float = 0.95                    # demand vector: min alloc @ 95% of max
    probe_seconds: float = 60.0           # ~1 min per empirical probe (§3.1)
    mem_unit_gb: float = 50.0             # memory discretization (§3.1 example)
    min_mem_gb: float = 20.0              # process working set floor


class OptimisticProfiler:
    def __init__(self, spec: ServerSpec = ServerSpec(),
                 cfg: ProfilerConfig = ProfilerConfig()):
        self.spec = spec
        self.cfg = cfg

    # -- grids -----------------------------------------------------------------
    def cpu_grid(self, gpus: int) -> np.ndarray:
        n_servers = max(1, -(-gpus // self.spec.gpus))
        max_cpu = int(n_servers * self.spec.cpus)
        return np.arange(1.0, max_cpu + 1.0)

    def mem_grid(self, gpus: int) -> np.ndarray:
        n_servers = max(1, -(-gpus // self.spec.gpus))
        max_mem = n_servers * self.spec.mem
        grid = set(np.arange(self.cfg.mem_unit_gb, max_mem + 1e-9,
                             self.cfg.mem_unit_gb).tolist())
        grid.add(gpus * self.spec.mem_per_gpu)      # GPU-proportional share
        grid.add(self.cfg.min_mem_gb)
        grid.add(max_mem)
        return np.asarray(sorted(g for g in grid if g <= max_mem + 1e-9))

    # -- the binary-search CPU probe placement (§3.1) ---------------------------
    def probe_cpu_curve(self, measure: Callable[[float], float],
                        cpu_points: np.ndarray) -> Dict[float, float]:
        probed: Dict[float, float] = {}

        def probe(idx: int) -> float:
            c = float(cpu_points[idx])
            if c not in probed:
                probed[c] = measure(c)
            return probed[c]

        lo, hi = 0, len(cpu_points) - 1
        probe(lo)
        probe(hi)
        while hi - lo > 1:
            mid = (lo + hi) // 2
            t_mid, t_hi = probe(mid), probe(hi)
            gain = (t_hi - t_mid) / max(t_mid, 1e-12)
            if gain < self.cfg.improvement_threshold:
                hi = mid          # knee is below: search lower half
            else:
                lo = mid          # real improvements above: search upper half
        return probed

    # -- optimistic matrix -------------------------------------------------------
    def profile(self, model: WorkloadModel, gpus: int,
                measure_fn: Optional[Callable[[float], float]] = None
                ) -> SensitivityMatrix:
        """Build W[c, m] from ~8 empirical CPU probes + the analytic mem model."""
        cpu_points = self.cpu_grid(gpus)
        mem_points = self.mem_grid(gpus)
        m_max = float(mem_points[-1])

        if measure_fn is None:          # simulator: ground truth at full memory
            def measure_fn(c: float) -> float:
                return throughput(model, gpus, c, m_max,
                                  min_mem_gb=self.cfg.min_mem_gb)

        probed = self.probe_cpu_curve(measure_fn, cpu_points)

        # piecewise-linear interpolation over the probed CPU points
        xs = np.asarray(sorted(probed))
        ys = np.asarray([probed[x] for x in xs])
        w_cpu = np.interp(cpu_points, xs, ys)

        # analytic memory fill: known storage bw + MinIO fixed hit rate
        b = model.batch_per_gpu * gpus
        cache = np.maximum(mem_points - self.cfg.min_mem_gb, 0.0)
        hit = np.minimum(1.0, cache / model.dataset_gb)
        t_fetch = b * (1.0 - hit) * model.sample_mb / model.disk_bw_mbps

        W = np.zeros((len(cpu_points), len(mem_points)))
        for ci in range(len(cpu_points)):
            t_star = b / max(w_cpu[ci], 1e-12)
            W[ci, :] = b / np.maximum(t_star, t_fetch)
        W[:, mem_points < self.cfg.min_mem_gb - 1e-9] = 0.0

        return SensitivityMatrix(
            cpu_points, mem_points, W, gpus,
            profile_probes=len(probed),
            profile_seconds=len(probed) * self.cfg.probe_seconds)

    # -- job-facing helpers --------------------------------------------------------
    def profile_job(self, job, measure_fn=None) -> None:
        if job.matrix is not None:      # already profiled (once per lifetime)
            return
        model = MODEL_ZOO[job.model_name]
        mat = self.profile(model, job.gpu_demand, measure_fn)
        job.matrix = mat
        cg, mg = (job.gpu_demand * self.spec.cpu_per_gpu,
                  job.gpu_demand * self.spec.mem_per_gpu)
        job.prop_rate = mat.rate(cg, mg)
        # The demand vector must reach at least GPU-proportional throughput
        # (fairness floor, §4.2) but otherwise be the knee of the curve.
        job.demand_cpu, job.demand_mem = mat.best_demand(
            self.cfg.knee, floor_rate=job.prop_rate)
        if mat.rate(job.demand_cpu, job.demand_mem) < job.prop_rate - 1e-12:
            job.demand_cpu, job.demand_mem = cg, mg
