"""The Synergy iterator (§4.3): the thin API between scheduler and DNN job.

The paper wraps PyTorch/DALI iterators and talks gRPC; here the iterator
wraps the JAX ``DataPipeline`` and talks over an in-process, thread-safe
control channel (the live runtime runs jobs as threads — a process+gRPC
transport would carry the same three message types):

  scheduler -> job:  LeaseUpdate(cpus, mem_gb)  |  LeaseTerminate
  job -> scheduler:  Progress(iters, t)

On ``LeaseUpdate`` the iterator retunes the pipeline (worker count == CPU
allocation, MinIO capacity == memory allocation). On ``LeaseTerminate`` it
checkpoints via the provided callback and stops iteration; the runtime
re-registers the job when it is scheduled again.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterator, Optional


@dataclass
class LeaseUpdate:
    cpus: float
    mem_gb: float


class LeaseTerminate:
    pass


@dataclass
class Progress:
    job_id: int
    iters: int
    t: float


class ControlChannel:
    """Per-job bidirectional channel (in-process stand-in for gRPC)."""

    def __init__(self, job_id: int):
        self.job_id = job_id
        self.to_job: "queue.Queue" = queue.Queue()
        self.to_scheduler: "queue.Queue" = queue.Queue()

    # scheduler side
    def send_lease(self, cpus: float, mem_gb: float) -> None:
        self.to_job.put(LeaseUpdate(cpus, mem_gb))

    def terminate(self) -> None:
        self.to_job.put(LeaseTerminate())

    def drain_progress(self):
        out = []
        while True:
            try:
                out.append(self.to_scheduler.get_nowait())
            except queue.Empty:
                return out


class SynergyIterator:
    """Wraps a DataPipeline; applies leases; reports progress."""

    def __init__(self, job_id: int, pipeline, channel: ControlChannel,
                 on_terminate: Optional[Callable[[], None]] = None,
                 report_every: int = 1):
        self.job_id = job_id
        self.pipeline = pipeline
        self.channel = channel
        self.on_terminate = on_terminate
        self.report_every = report_every
        self.iters = 0
        self.terminated = False

    def _poll_control(self) -> bool:
        """Apply pending control messages; False => lease terminated."""
        while True:
            try:
                msg = self.channel.to_job.get_nowait()
            except queue.Empty:
                return True
            if isinstance(msg, LeaseUpdate):
                self.pipeline.set_workers(int(round(msg.cpus)))
                self.pipeline.set_cache_gb(msg.mem_gb)
            elif isinstance(msg, LeaseTerminate):
                return False

    def __iter__(self) -> Iterator[dict]:
        gen = self.pipeline.batches(10 ** 9)
        while True:
            if not self._poll_control():
                self.terminated = True
                if self.on_terminate:
                    self.on_terminate()
                return
            try:
                batch = next(gen)
            except StopIteration:
                return
            yield batch
            self.iters += 1
            if self.iters % self.report_every == 0:
                self.channel.to_scheduler.put(
                    Progress(self.job_id, self.iters, time.time()))
