"""Job model.

A job arrives with a fixed GPU demand (never altered — §3 'GPU demands are
left unaltered for the lifetime of a job') and a workload model name. After
optimistic profiling it carries a sensitivity matrix and a best-case demand
vector (g, c*, m*); the scheduler arbitrates only (c, m).

Progress accounting: ``duration`` is the job's runtime under GPU-proportional
allocation (how trace durations are defined, §5.1). Each scheduling round the
job advances by ``dt * current_rate / prop_rate`` proportional-seconds.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.core.sensitivity import SensitivityMatrix


@dataclass
class Job:
    job_id: int
    model_name: str
    gpu_demand: int
    arrival_time: float
    duration: float                      # seconds under GPU-proportional alloc
    arch_id: Optional[str] = None        # assigned-architecture job (live runtime)

    # -- filled by the profiler ------------------------------------------------
    matrix: Optional[SensitivityMatrix] = None
    demand_cpu: float = 0.0              # best-case CPU demand (job total)
    demand_mem: float = 0.0              # best-case memory demand (GB)
    prop_rate: float = 0.0               # W[Cg, Mg] — GPU-proportional rate
    profile_overhead_s: float = 0.0      # wall-clock spent profiling (§5)

    # -- runtime state ----------------------------------------------------------
    remaining: float = field(default=-1.0)   # proportional-seconds left
    current_rate: float = 0.0
    attained_service: float = 0.0        # GPU-seconds of service (LAS)
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    n_preemptions: int = 0

    def __post_init__(self):
        if self.remaining < 0:
            self.remaining = self.duration

    # ------------------------------------------------------------------------
    @property
    def speedup(self) -> float:
        if self.prop_rate <= 0:
            return 1.0 if self.current_rate > 0 else 0.0
        return self.current_rate / self.prop_rate

    def demand_vector(self) -> Tuple[int, float, float]:
        return self.gpu_demand, self.demand_cpu, self.demand_mem

    def advance(self, dt: float) -> float:
        """Advance by wall-clock dt; returns proportional-work done."""
        work = dt * self.speedup
        self.remaining = max(0.0, self.remaining - work)
        if self.current_rate > 0:
            self.attained_service += dt * self.gpu_demand
        return work

    def time_to_finish(self) -> float:
        """Wall-clock time to completion at the current rate (inf if idle)."""
        if self.remaining <= 0:
            return 0.0
        if self.current_rate <= 0 or self.speedup <= 0:
            return float("inf")
        return self.remaining / self.speedup

    @property
    def finished(self) -> bool:
        return self.remaining <= 1e-9

    def jct(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time
