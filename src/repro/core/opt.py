"""Synergy-OPT (§4.1, §A.1): the two-LP upper bound / feasible placement.

LP1 (ideal single super-machine): pick one (c, m) option per job maximizing
total throughput s.t. capacity + fairness (>= GPU-proportional throughput).
Solved with scipy HiGHS — as the LP relaxation (Theorem 4.1: an upper bound
on any feasible solution) and optionally as the ILP (tighter bound, what the
paper runs via CVXPY).

LP2 (placement): spread the chosen (g_j, c*_j, m*_j) demand vectors across s
machines minimizing fragmentation; Theorem A.2 bounds fragmented jobs by 3s.

The per-job option set is pruned to its Pareto frontier ((c,m) minimal for
each achievable throughput) — identical optimum, much smaller program.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy import optimize, sparse

from repro.core.cluster import Cluster
from repro.core.job import Job


def pareto_options(job: Job) -> List[Tuple[float, float, float]]:
    opts = job.matrix.options()
    opts.sort(key=lambda t: (t[0], t[1]))
    keep = []
    for c, m, w in opts:
        dominated = any(c2 <= c and m2 <= m and w2 >= w and (c2, m2) != (c, m)
                        for c2, m2, w2 in keep)
        if not dominated:
            keep = [(c2, m2, w2) for c2, m2, w2 in keep
                    if not (c <= c2 and m <= m2 and w >= w2)]
            keep.append((c, m, w))
    return keep


@dataclass
class OptResult:
    alloc: Dict[int, Tuple[float, float]]          # job -> (c*, m*)
    throughput: float                               # objective value
    fair_throughput: float                          # sum of W[Cg, Mg]
    solve_seconds: float
    is_integral: bool
    placement: Optional[Dict[int, List[Tuple[int, float]]]] = None
    fragmented_jobs: int = 0
    lp2_seconds: float = 0.0
    status: str = "ok"


def solve_ideal(jobs: Sequence[Job], cluster: Cluster,
                integer: bool = True, time_limit: float = 60.0) -> OptResult:
    """LP1/ILP1: ideal allocation on the super-machine (eqs. 1–5)."""
    t0 = time.perf_counter()
    C, M = cluster.total_cpus, cluster.total_mem

    opts: List[Tuple[int, float, float, float]] = []    # (job_idx, c, m, w)
    job_slices: List[Tuple[int, int]] = []
    fair = []
    for ji, job in enumerate(jobs):
        cg, mg = cluster.proportional_demand(job.gpu_demand)
        w_fair = job.matrix.rate(cg, mg)
        fair.append(w_fair)
        lo = len(opts)
        for c, m, w in pareto_options(job):
            opts.append((ji, c, m, w))
        job_slices.append((lo, len(opts)))

    nv = len(opts)
    n = len(jobs)
    cvec = np.array([o[1] for o in opts])
    mvec = np.array([o[2] for o in opts])
    wvec = np.array([o[3] for o in opts])

    rows, cols, vals = [], [], []
    b_lo, b_hi = [], []
    # capacity constraints (2),(3)
    rows += [0] * nv + [1] * nv
    cols += list(range(nv)) * 2
    vals += list(cvec) + list(mvec)
    b_lo += [-np.inf, -np.inf]
    b_hi += [C, M]
    # one configuration per job (4)
    for ji, (lo, hi) in enumerate(job_slices):
        rows += [2 + ji] * (hi - lo)
        cols += list(range(lo, hi))
        vals += [1.0] * (hi - lo)
        b_lo.append(1.0)
        b_hi.append(1.0)
    # fairness (5)
    for ji, (lo, hi) in enumerate(job_slices):
        rows += [2 + n + ji] * (hi - lo)
        cols += list(range(lo, hi))
        vals += list(wvec[lo:hi])
        b_lo.append(fair[ji])
        b_hi.append(np.inf)

    A = sparse.csr_matrix((vals, (rows, cols)), shape=(2 + 2 * n, nv))
    constraints = optimize.LinearConstraint(A, np.array(b_lo), np.array(b_hi))
    integrality = np.ones(nv) if integer else np.zeros(nv)
    res = optimize.milp(
        c=-wvec, constraints=constraints,
        bounds=optimize.Bounds(0.0, 1.0),
        integrality=integrality,
        options={"time_limit": time_limit, "presolve": True})

    dt = time.perf_counter() - t0
    if res.x is None:
        return OptResult({}, 0.0, sum(fair), dt, integer, status="infeasible")

    alloc: Dict[int, Tuple[float, float]] = {}
    for ji, (lo, hi) in enumerate(job_slices):
        x = res.x[lo:hi]
        best = lo + int(np.argmax(x))
        alloc[jobs[ji].job_id] = (opts[best][1], opts[best][2])
    return OptResult(alloc, float(-res.fun), float(sum(fair)), dt, integer)


def solve_placement(jobs: Sequence[Job], cluster: Cluster,
                    alloc: Dict[int, Tuple[float, float]]) -> Tuple[
                        Dict[int, List[Tuple[int, float]]], int, float]:
    """LP2 (eqs. 15–19): fractional placement minimizing fragmentation.

    Returns ({job -> [(server, fraction)]}, n_fragmented, seconds).
    """
    t0 = time.perf_counter()
    s = len(cluster.servers)
    n = len(jobs)
    nv = s * n

    def vid(i, j):
        return i * n + j

    g = np.array([j.gpu_demand for j in jobs], float)
    c = np.array([alloc[j.job_id][0] for j in jobs])
    m = np.array([alloc[j.job_id][1] for j in jobs])

    rows, cols, vals, b_lo, b_hi = [], [], [], [], []
    r = 0
    for i in range(s):                      # per-machine capacities (15)-(17)
        for arr, cap in ((g, cluster.spec.gpus), (c, cluster.spec.cpus),
                         (m, cluster.spec.mem)):
            for j in range(n):
                rows.append(r)
                cols.append(vid(i, j))
                vals.append(arr[j])
            b_lo.append(-np.inf)
            b_hi.append(cap)
            r += 1
    for j in range(n):                      # full allocation (18)
        for i in range(s):
            rows.append(r)
            cols.append(vid(i, j))
            vals.append(1.0)
        b_lo.append(1.0)
        b_hi.append(np.inf)
        r += 1

    A = sparse.csr_matrix((vals, (rows, cols)), shape=(r, nv))
    # LP (no integrality): Theorem A.2's vertex-solution argument is about the
    # *fractional* optimum — at most 3s jobs fragmented.
    res = optimize.milp(
        c=np.ones(nv),
        constraints=optimize.LinearConstraint(A, np.array(b_lo), np.array(b_hi)),
        bounds=optimize.Bounds(0.0, np.inf),
        integrality=np.zeros(nv))

    dt = time.perf_counter() - t0
    placement: Dict[int, List[Tuple[int, float]]] = {}
    fragmented = 0
    if res.x is not None:
        x = res.x.reshape(s, n)
        for j, job in enumerate(jobs):
            locs = [(i, float(x[i, j])) for i in range(s) if x[i, j] > 1e-6]
            placement[job.job_id] = locs
            if len(locs) > 1:
                fragmented += 1
    return placement, fragmented, dt


def greedy_allocate(curves: Sequence, total: float, *,
                    weights: Optional[Sequence[float]] = None,
                    floors: Optional[Sequence[float]] = None,
                    quantum: float = 1.0) -> List[float]:
    """Greedy near-optimal split of ``total`` units of ONE resource.

    The online sibling of ``solve_ideal``: maximize
    ``sum_i w_i * curve_i(x_i)`` subject to ``sum_i x_i <= total`` and
    ``x_i >= floor_i`` by repeatedly handing the next ``quantum`` to the
    consumer with the highest weighted marginal gain. Optimal for concave
    curves; near-optimal for the knee-shaped sensitivity curves Synergy
    profiles (§4 — the serve-side ``TenantAllocator`` builds on this).

    Step-shaped curves (a serve tenant's rate only jumps every
    ``units_per_req`` units) are handled by lookahead: each consumer's
    gain is the weighted RATE over the smallest stride of quanta that
    shows one, and the winner receives that whole stride — a curve whose
    jump granularity exceeds the quantum is not mistaken for saturated.

    Once every curve is saturated (no positive gain within the remaining
    budget) the remainder is handed out by weight so the budgets cover
    the pool.
    """
    n = len(curves)
    if n == 0:
        return []
    w = list(weights) if weights is not None else [1.0] * n
    x = [float(f) for f in (floors if floors is not None else [0.0] * n)]
    if sum(x) > total + 1e-9:
        raise ValueError(
            f"floors {x} already exceed the pool ({total} units)")
    left = total - sum(x)
    while left >= quantum:
        best_i, best_rate, best_stride = -1, 0.0, 0
        for i in range(n):
            base = curves[i](x[i])
            j = 1
            while j * quantum <= left + 1e-9:
                d = curves[i](x[i] + j * quantum) - base
                if d > 1e-12:
                    rate = w[i] * d / j
                    if rate > best_rate:
                        best_i, best_rate, best_stride = i, rate, j
                    break
                j += 1
        if best_i < 0:
            break
        x[best_i] += best_stride * quantum
        left -= best_stride * quantum
    # all curves flat: spread the remainder by weight (largest first) so
    # the per-consumer budgets still cover the whole pool.
    order = sorted(range(n), key=lambda i: (-w[i], i))
    j = 0
    while left >= quantum:
        x[order[j % n]] += quantum
        left -= quantum
        j += 1
    return x


def solve(jobs: Sequence[Job], cluster: Cluster, integer: bool = True,
          with_placement: bool = False, time_limit: float = 60.0) -> OptResult:
    result = solve_ideal(jobs, cluster, integer=integer, time_limit=time_limit)
    if with_placement and result.alloc:
        placement, frag, dt2 = solve_placement(jobs, cluster, result.alloc)
        result.placement = placement
        result.fragmented_jobs = frag
        result.lp2_seconds = dt2
    return result
