"""Dispatch-level profiling: measured per-dispatch cost + analytic roofline.

The measurement half of Synergy's optimistic-profiling loop, applied to the
serve engine: the tenant profiler (serve/tenant.py) FITS sensitivity curves
from two probes, the allocator plans from the fits — but until now nothing
MEASURED what a dispatch actually costs, so the fits rode on analytic
guesses. ``DispatchProfiler`` wraps every jitted hot path (batched prefill
rounds, K-step decode horizons — the compaction gathers/scatters ride
inside the horizon program and are tagged by its ``full`` flag) and records
per-dispatch wall time with:

  * **compile-vs-execute attribution** — jit compiles one program per
    static signature (phase, width bucket, horizon K, full/compacted,
    prompt length), so the FIRST call carrying a new signature is the
    compile+execute and every later call is pure execute; the profiler
    keeps the seen-signature set across runs, which is exactly how the
    warm-run benchmarks already reason about cost.
  * **an analytic roofline term per signature** — FLOPs and HBM bytes
    computed from the config shapes (the same model-FLOPs convention
    ``launch/dryrun.py`` records: 2·N_active·tokens, plus per-position KV
    traffic), against the TPU-v5e peaks ``launch/mesh.py`` publishes — so
    every execute dispatch gets a measured-vs-roofline utilization ratio.
  * **per-tenant cost shares** — dispatch seconds split by lane/slot
    occupancy (a decode horizon whose bucket holds 3 rows of tenant A and
    1 of tenant B charges A 75% of the dispatch).

Records flow three ways: gauges + boundary-sampled series in the run's
``MetricsRegistry`` (``util[decode]`` etc. — the Chrome exporter renders
them as counter tracks), ``dispatch_profile`` events into the run's
``Tracer`` when one is attached (so ``trace_report`` can print utilization
per phase), and aggregated per-(arch × phase × geometry) records into a
``ProfileStore``.

``ProfileStore`` persists to ``experiments/profiles.jsonl`` (one JSON
record per line, keyed merge — re-runs supersede) and closes the loop:
``rate_fit`` regresses the decode records onto the tenant profiler's rate
model ``dur = t_fixed + rows·K·t_tok``, so ``serve/tenant.py``'s
``calibrate`` path can build its ``SensitivityMatrix`` knees from MEASURED
constants when a store is present (flag-gated; the analytic fallback
stays). ``launch/run_all_dryruns.py`` feeds the same store with the
roofline terms the dry-run sweep computes, so placement profiling
(ROADMAP item 5) and live re-planning (item 1) read one substrate.

Profiling is read-only — it never touches computation, so ``--verify``
token identity holds with it on — and off is the default: the engine
holds the falsy ``NULL_PROFILER`` and every hook site guards with one
truthiness check (``if prof: ...``), the same contract as ``NULL_TRACER``.

This module stays jax/numpy-free (like the rest of ``repro.obs``) so
``trace_report`` and store tooling run anywhere the files land; the
roofline peaks are resolved lazily from ``launch.mesh`` when a real
profiler is built, with the v5e constants as the import-free fallback.
"""
from __future__ import annotations

import json
import os
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

#: fallback roofline peaks (TPU v5e, per chip) — mirrors ``launch.mesh``;
#: ``DispatchProfiler`` prefers the live import so the numbers cannot drift.
_PEAK_FLOPS_BF16 = 197e12
_HBM_BW = 819e9

_ACT_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}

#: phases with an attention-KV read/write pattern (per-position cache
#: traffic); recurrent families carry O(1) state instead and their cache
#: traffic is folded into the (dominant) parameter-read term.
_ATTN_FAMILIES = ("dense", "vlm", "moe", "encdec")


def _dtype_bytes(name: str) -> int:
    return _ACT_BYTES.get(str(name), 4)


class NullDispatchProfiler:
    """The profiling-off profiler: falsy, every hook a no-op.

    The engine's default — ``if prof:`` short-circuits every hook site, so
    a run without profiling pays one truthiness check per site and nothing
    else (the same no-measurable-overhead contract as ``NULL_TRACER``).
    """
    enabled = False
    records: List[dict] = []
    tenant_s: Dict[str, float] = {}

    def __bool__(self) -> bool:
        return False

    def record(self, phase: str, dur_s: float, **kw) -> None:
        pass

    def summary(self) -> dict:
        return {}


NULL_PROFILER = NullDispatchProfiler()


class DispatchProfiler:
    """Per-dispatch wall-time recorder with roofline attribution.

    ``cfg`` (an ``ArchConfig``) supplies the shapes the analytic FLOP/byte
    model reads; without one the profiler still measures and attributes
    compile-vs-execute but reports no roofline terms. ``n_devices`` splits
    the analytic terms per chip for sharded engines (SPMD divides the work;
    the measured wall time is already per-program).
    """
    enabled = True

    def __init__(self, cfg=None, *, n_devices: int = 1,
                 peak_flops: Optional[float] = None,
                 hbm_bw: Optional[float] = None):
        if peak_flops is None or hbm_bw is None:
            try:        # live peaks (needs jax); fallback mirrors them
                from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
                peak_flops = peak_flops or PEAK_FLOPS_BF16
                hbm_bw = hbm_bw or HBM_BW
            except Exception:
                peak_flops = peak_flops or _PEAK_FLOPS_BF16
                hbm_bw = hbm_bw or _HBM_BW
        self.cfg = cfg
        self.n_devices = max(int(n_devices), 1)
        self.peak_flops = float(peak_flops)
        self.hbm_bw = float(hbm_bw)
        self.records: List[dict] = []
        self.tenant_s: Dict[str, float] = {}
        self._seen: set = set()
        self._t0 = time.perf_counter()
        # config-derived constants, computed once (param_count walks the
        # whole arithmetic; the hot path should not)
        if cfg is not None:
            self._params_active = cfg.param_count(active_only=True)
            self._param_bytes = (cfg.param_count()
                                 * _dtype_bytes(cfg.param_dtype))
            if cfg.family in _ATTN_FAMILIES:
                self._kv_bytes_per_pos = (cfg.n_layers * 2 * cfg.n_kv_heads
                                          * cfg.resolved_head_dim
                                          * _dtype_bytes(cfg.dtype))
            else:
                self._kv_bytes_per_pos = 0
        else:
            self._params_active = 0
            self._param_bytes = 0
            self._kv_bytes_per_pos = 0

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self.records)

    # -- analytic roofline ---------------------------------------------------
    def roofline_terms(self, phase: str, *, tokens: int, k: int = 1,
                       kv_pos_sum: int = 0) -> Tuple[float, float]:
        """(FLOPs, HBM bytes) one dispatch moves, from the config shapes.

        FLOPs use the model-FLOPs convention the dry-run records
        (2·N_active per token — attention's quadratic term is excluded on
        both sides of the comparison, so ratios stay consistent). HBM
        bytes: the parameters are re-read every scan step of a decode
        horizon (k times) and once per prefill chunk; the KV cache
        contributes ``kv_pos_sum`` read positions per step plus one write
        per computed token. Deliberately analytic — the point is a STABLE
        per-signature denominator, not a byte-exact trace."""
        if self.cfg is None:
            return 0.0, 0.0
        flops = 2.0 * self._params_active * tokens
        weight_reads = k if phase == "decode" else 1
        hbm = (weight_reads * self._param_bytes
               + (kv_pos_sum * weight_reads + tokens)
               * self._kv_bytes_per_pos)
        return flops, float(hbm)

    # -- the hook ------------------------------------------------------------
    def record(self, phase: str, dur_s: float, *, width: int = 1, k: int = 1,
               tokens: Optional[int] = None, kv_pos_sum: int = 0,
               full: Optional[bool] = None, seq: Optional[int] = None,
               tenants: Optional[Dict[str, int]] = None, obs=None) -> dict:
        """Record one jitted dispatch.

        ``width``/``k``/``full``/``seq`` are the STATIC half of the call —
        they name the XLA program, so they form the signature whose first
        sighting is the compile. ``tokens`` defaults to ``width * k`` (the
        dispatched compute — padded rows compute too). ``kv_pos_sum`` is
        the summed KV positions of the dispatched rows (the cache-read
        term). ``tenants`` maps tenant id -> rows in this dispatch (cost
        shares). ``obs`` (a ``RunObs``) receives the utilization gauge and
        the ``dispatch_profile`` trace event when its tracer is live."""
        tokens = int(width * k) if tokens is None else int(tokens)
        sig = f"{phase}/W{width}/K{k}"
        if full is not None:
            sig += "/full" if full else "/gather"
        if seq is not None:
            sig += f"/S{seq}"
        first = sig not in self._seen
        self._seen.add(sig)
        flops, hbm = self.roofline_terms(phase, tokens=tokens, k=k,
                                         kv_pos_sum=kv_pos_sum)
        roof_s = max(flops / self.peak_flops, hbm / self.hbm_bw) \
            / self.n_devices
        util = (roof_s / dur_s) if (not first and dur_s > 0 and roof_s > 0) \
            else None
        rec = {"phase": phase, "sig": sig, "dur_s": float(dur_s),
               "compile": first, "tokens": tokens, "width": int(width),
               "k": int(k), "flops": flops, "hbm_bytes": hbm,
               "util": util, "t": time.perf_counter() - self._t0}
        self.records.append(rec)
        if tenants:
            total = sum(tenants.values())
            if total > 0:
                for tid, rows in tenants.items():
                    self.tenant_s[tid] = (self.tenant_s.get(tid, 0.0)
                                          + dur_s * rows / total)
        if obs is not None:
            if util is not None:
                obs.metrics.set(f"util[{phase}]", util)
            obs.inc(f"{'compile' if first else 'execute'}_s[{phase}]", dur_s)
            if obs.tracer:
                obs.tracer.emit("dispatch_profile", phase=phase, sig=sig,
                                dur_s=float(dur_s), compile=first,
                                tokens=tokens, flops=flops, hbm_bytes=hbm,
                                util=util)
        return rec

    # -- aggregation ---------------------------------------------------------
    def by_signature(self) -> "OrderedDict[str, dict]":
        """Per-signature aggregate: dispatch count, compile/execute wall
        split, mean execute seconds, mean utilization (execute-only)."""
        out: "OrderedDict[str, dict]" = OrderedDict()
        for r in self.records:
            g = out.setdefault(r["sig"], {
                "phase": r["phase"], "sig": r["sig"], "width": r["width"],
                "k": r["k"], "tokens": r["tokens"], "flops": r["flops"],
                "hbm_bytes": r["hbm_bytes"], "n": 0, "compiles": 0,
                "compile_s": 0.0, "execute_s": 0.0, "utils": []})
            g["n"] += 1
            if r["compile"]:
                g["compiles"] += 1
                g["compile_s"] += r["dur_s"]
            else:
                g["execute_s"] += r["dur_s"]
                if r["util"] is not None:
                    g["utils"].append(r["util"])
        for g in out.values():
            execs = g["n"] - g["compiles"]
            g["mean_execute_s"] = g["execute_s"] / execs if execs else 0.0
            g["util"] = (sum(g["utils"]) / len(g["utils"])
                         if g["utils"] else None)
            del g["utils"]
        return out

    def summary(self) -> dict:
        """Per-phase rollup + tenant cost shares (the launch JSON block)."""
        phases: Dict[str, dict] = {}
        for g in self.by_signature().values():
            p = phases.setdefault(g["phase"], {
                "dispatches": 0, "compiles": 0, "compile_s": 0.0,
                "execute_s": 0.0, "utils": []})
            p["dispatches"] += g["n"]
            p["compiles"] += g["compiles"]
            p["compile_s"] += g["compile_s"]
            p["execute_s"] += g["execute_s"]
            if g["util"] is not None:
                p["utils"].append(g["util"])
        for p in phases.values():
            p["util"] = (sum(p["utils"]) / len(p["utils"])
                         if p["utils"] else None)
            del p["utils"]
        total = sum(self.tenant_s.values())
        shares = {t: s / total for t, s in sorted(self.tenant_s.items())} \
            if total > 0 else {}
        return {"phases": phases, "tenant_seconds": dict(self.tenant_s),
                "tenant_shares": shares, "signatures": len(self._seen),
                "dispatches": len(self.records)}


# ---------------------------------------------------------------------------
# the profile store
# ---------------------------------------------------------------------------
def _store_key(rec: dict) -> tuple:
    return (rec.get("source"), rec.get("arch"), rec.get("backend"),
            rec.get("phase"), rec.get("sig"))


class ProfileStore:
    """Persisted per-(arch × phase × geometry) dispatch-cost records.

    One JSON record per line in ``experiments/profiles.jsonl``; records are
    keyed by (source, arch, backend, phase, sig) and the last write wins —
    re-profiled geometries supersede, the same discipline as the dry-run
    JSONL. Two sources feed it: ``add_run`` (a serve engine's
    ``DispatchProfiler`` — measured) and ``add_dryrun_record`` (the
    lowering sweep's analytic roofline terms — ``run_all_dryruns
    --profile-store``). ``rate_fit`` is the read side the tenant
    profiler's measured-calibrate path consumes.
    """

    def __init__(self, records: Optional[List[dict]] = None):
        self._recs: "OrderedDict[tuple, dict]" = OrderedDict()
        for r in records or []:
            self.add(r)

    def __len__(self) -> int:
        return len(self._recs)

    @property
    def records(self) -> List[dict]:
        return list(self._recs.values())

    def add(self, rec: dict) -> None:
        self._recs[_store_key(rec)] = dict(rec)

    @classmethod
    def load(cls, path: str) -> "ProfileStore":
        """Read a store from JSONL (a missing file is an empty store — the
        flag-gated measured-calibrate path falls back to analytic)."""
        store = cls()
        if not os.path.exists(path):
            return store
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    store.add(json.loads(line))
        return store

    def save(self, path: str) -> None:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            for rec in self._recs.values():
                f.write(json.dumps(rec) + "\n")

    # -- writers -------------------------------------------------------------
    def add_run(self, prof: DispatchProfiler, *, arch: str, backend: str,
                mesh: Optional[str] = None) -> int:
        """Fold one profiled engine run in: one record per dispatch
        signature, measured means + roofline terms. Returns records added."""
        n = 0
        for g in prof.by_signature().values():
            execs = g["n"] - g["compiles"]
            self.add({
                "source": "serve", "arch": arch, "backend": backend,
                "mesh": mesh, "phase": g["phase"], "sig": g["sig"],
                "width": g["width"], "k": g["k"], "tokens": g["tokens"],
                "n": execs, "compiles": g["compiles"],
                "compile_s": g["compile_s"],
                "mean_s": g["mean_execute_s"],
                "flops": g["flops"], "hbm_bytes": g["hbm_bytes"],
                "util": g["util"],
            })
            n += 1
        return n

    def add_dryrun_record(self, rec: dict) -> None:
        """Convert one ``launch/dryrun.py`` JSONL record into a store
        record: the analytic roofline terms per (arch × shape × mesh) the
        placement loop (ROADMAP item 5) reads next to the measured serve
        records."""
        self.add({
            "source": "dryrun", "arch": rec["arch"], "backend": rec["mesh"],
            "mesh": rec["mesh"], "phase": rec["mode"],
            "sig": f"{rec['mode']}/{rec['shape']}",
            "width": None, "k": 1, "tokens": None,
            "n": 1, "compiles": 1, "compile_s": rec.get("compile_s", 0.0),
            "mean_s": max(rec.get("compute_s", 0.0),
                          rec.get("memory_s", 0.0),
                          rec.get("collective_s", 0.0)),
            "flops": rec.get("flops_per_chip"),
            "hbm_bytes": rec.get("bytes_per_chip"),
            "util": rec.get("useful_flop_ratio"),
            "bottleneck": rec.get("bottleneck"),
        })

    # -- the read side: measured rate constants ------------------------------
    def rate_fit(self, arch: str, backend: Optional[str] = None,
                 ) -> Optional[Tuple[float, float]]:
        """Fit the tenant rate model's constants from measured decode
        records: ``dur = t_fixed + rows·K·t_tok`` is linear in the
        dispatched token count, so weighted least squares over the
        per-signature (width·k, mean_s) points recovers (t_tok, t_fixed).
        Returns None without at least two distinct dispatch sizes or when
        the slope is non-positive (degenerate measurement) — the caller
        keeps its analytic constants then."""
        pts = []
        for r in self._recs.values():
            if (r.get("source") == "serve" and r.get("arch") == arch
                    and r.get("phase") == "decode" and r.get("n", 0) > 0
                    and (backend is None or r.get("backend") == backend)):
                pts.append((float(r["width"] * r["k"]),
                            float(r["mean_s"]), float(r["n"])))
        if len({x for x, _, _ in pts}) < 2:
            return None
        sw = sum(w for _, _, w in pts)
        mx = sum(x * w for x, _, w in pts) / sw
        my = sum(y * w for _, y, w in pts) / sw
        sxx = sum(w * (x - mx) ** 2 for x, _, w in pts)
        sxy = sum(w * (x - mx) * (y - my) for x, y, w in pts)
        if sxx <= 0:
            return None
        t_tok = sxy / sxx
        if t_tok <= 0:
            return None
        t_fixed = max(0.0, my - t_tok * mx)
        return t_tok, t_fixed
