"""Observability: structured event tracing + live metrics for the serve
engine.

Three pieces (see serve/README.md "Observability" for the taxonomy and a
worked example):

  * ``obs.events``  — ring-buffered JSONL event tracer (``Tracer``), the
    event taxonomy (``EVENT_SCHEMA``), and the falsy no-op ``NullTracer``
    the engine holds when tracing is off.
  * ``obs.metrics`` — counters / gauges / histograms + boundary-sampled
    time series (``MetricsRegistry``); always on — ``ServeStats`` is
    built from it.
  * ``obs.chrome``  — Chrome trace-event (Perfetto-viewable) export.
  * ``obs.prof``    — dispatch-level profiler (``DispatchProfiler``):
    per-dispatch wall time with compile-vs-execute attribution, analytic
    roofline utilization, per-tenant cost shares, and the persisted
    ``ProfileStore`` that feeds the tenant profiler's measured-calibrate
    path. ``NULL_PROFILER`` is the falsy off-state the engine holds by
    default.

``launch/trace_report.py`` is the offline analyzer over dumped traces.
"""
from repro.obs.chrome import to_chrome_trace, write_chrome_trace
from repro.obs.events import (EVENT_SCHEMA, NULL_TRACER, SPAN_EVENTS,
                              NullTracer, Tracer, load_trace, read_trace,
                              validate_events)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               RunObs)
from repro.obs.prof import (NULL_PROFILER, DispatchProfiler,
                            NullDispatchProfiler, ProfileStore)

__all__ = [
    "Counter", "DispatchProfiler", "EVENT_SCHEMA", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_PROFILER", "NULL_TRACER", "NullDispatchProfiler",
    "NullTracer", "ProfileStore", "RunObs", "SPAN_EVENTS", "Tracer",
    "load_trace", "read_trace", "to_chrome_trace", "validate_events",
    "write_chrome_trace",
]
