"""Structured event tracing: a ring-buffered event log for the serve engine.

The telemetry substrate Synergy-style scheduling needs: decisions must be
*observed*, not assumed (the same argument PAPER.md makes for per-job
resource sensitivity), and event-level traces are what make utilization and
queueing pathologies diagnosable at all (Jeon et al., arXiv:1901.05758).

An event is one flat dict:

    {"ev": <type>, "step": <engine decode-step clock>,
     "t": <wall seconds since tracer start>, ...payload}

``EVENT_SCHEMA`` is the taxonomy — every type's exact payload field set.
The schema is a stability contract: ``tests/test_obs.py`` pins it with a
golden trace, and ``launch/trace_report.py`` replays traces against it, so
adding a field means extending the schema (append-only), never mutating an
existing type in place.

``Tracer`` is a bounded ring: events past ``capacity`` drop the OLDEST
entry (``dropped`` counts them) so a long run's tail — usually what you
are debugging — survives at a fixed memory cost. ``NullTracer`` is the
tracing-off stand-in: it is falsy and its hooks do nothing, so every
instrumentation site in the engine guards with a single truthiness check
(``if tr: tr.emit(...)``) and tracing off costs one branch per site.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, FrozenSet, List, Optional, Tuple

#: event taxonomy: type -> exact payload field set (beyond ev/step/t).
#: Span events additionally carry ``dur_s`` (listed explicitly). The
#: golden-trace test asserts emitted events match these sets EXACTLY, so
#: schema drift is a deliberate, reviewed change.
EVENT_SCHEMA: Dict[str, FrozenSet[str]] = {
    # -- run lifecycle ------------------------------------------------------
    "run_start": frozenset({"backend", "n_slots", "horizon", "n_requests"}),
    "run_end": frozenset({"steps", "wall_s"}),
    # -- scheduler decisions ------------------------------------------------
    "admit": frozenset({"req", "tenant", "slot", "prompt_len", "max_new",
                        "wait_steps", "units"}),
    "evict": frozenset({"req", "tenant", "slot", "latency_steps",
                        "finished_early", "slo_steps", "met"}),
    "preempt": frozenset({"req", "tenant", "slot", "cause", "n_preempted"}),
    "budget_skip": frozenset({"req", "tenant", "held", "need", "budget"}),
    "defer": frozenset({"req", "tenant", "cause"}),
    # -- phase dispatches (spans: carry dur_s) ------------------------------
    "prefill": frozenset({"req", "tenant", "slot", "prompt_len", "dur_s"}),
    "prefill_round": frozenset({"lanes", "width", "dur_s"}),
    "decode_horizon": frozenset({"k", "width", "active", "full", "dur_s"}),
    "horizon_shrink": frozenset({"from_k", "to_k", "cause"}),
    # -- dispatch profiling (obs/prof.py; emitted only when a profiler AND
    # a tracer are both attached) -------------------------------------------
    "dispatch_profile": frozenset({"phase", "sig", "dur_s", "compile",
                                   "tokens", "flops", "hbm_bytes", "util"}),
    # -- fault injection (serve/chaos.py; emitted only with an injector) ----
    # ``target``: slot id / tenant / None; ``mag``: the kind's magnitude
    # (blocks revoked, hold steps, burst size, entries flushed).
    "fault_inject": frozenset({"kind", "target", "mag"}),
    # a recovery action the engine took for an injected fault: action in
    # {regenerate, retry, drop, restore, reserve_rescale, replan, noop};
    # ``req`` is the affected request id (None for pool-wide actions).
    "recover": frozenset({"kind", "action", "req", "detail"}),
    # -- elastic reshapes (serve/elastic.py; emitted at horizon boundaries) -
    # ``units``: the capacity delta applied (may be less than planned when
    # the pool could not satisfy it); ``capacity``: pool capacity AFTER;
    # ``dmult``: the mesh 'data' bucketing multiple after the reshape;
    # ``reason``: device_fail / device_join / occupancy / queue_depth /
    # slack.
    "scale_up": frozenset({"units", "capacity", "dmult", "reason"}),
    "scale_down": frozenset({"units", "capacity", "dmult", "reason"}),
    # a physical-growth state migration (BlockManager.grow_physical):
    # ``blocks`` existing blocks whose content moved into the new buffers.
    "migrate": frozenset({"blocks", "added", "dur_s"}),
    # -- block pool ---------------------------------------------------------
    "block_alloc": frozenset({"slot", "blocks", "hits"}),
    "block_grow": frozenset({"slot", "blocks"}),
    "block_free": frozenset({"slot", "blocks", "shared"}),
    "prefix_evict": frozenset({"blocks"}),
    # -- metadata (first line of a dumped trace) ----------------------------
    "trace_meta": frozenset({"events", "dropped", "capacity"}),
}

#: span types: rendered as duration tracks by the Chrome exporter
SPAN_EVENTS = frozenset({"prefill", "prefill_round", "decode_horizon",
                         "migrate"})


class NullTracer:
    """The tracing-off tracer: falsy, every hook a no-op.

    The engine's default — ``if tr:`` short-circuits every instrumentation
    site, so a run without tracing pays one truthiness check per site and
    nothing else (the no-measurable-overhead contract ``benchmarks.run
    --check`` gates).
    """
    enabled = False
    step: float = 0.0
    dropped = 0
    events: List[dict] = []

    def __bool__(self) -> bool:
        return False

    def emit(self, ev: str, step: Optional[float] = None, **fields) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Ring-buffered structured event log.

    ``capacity`` bounds memory: once full, each new event drops the OLDEST
    one and bumps ``dropped``. ``step`` is the engine's decode-step clock —
    the engine advances it, so call sites that have no clock of their own
    (the block pool) inherit the current step. Wall time is
    ``time.perf_counter`` relative to tracer construction (monotonic,
    sub-microsecond).
    """
    enabled = True

    def __init__(self, capacity: int = 1 << 16):
        if capacity < 1:
            raise ValueError("tracer capacity must be >= 1")
        self.capacity = int(capacity)
        self._events: deque = deque()
        self.dropped = 0
        self.step: float = 0.0
        self._t0 = time.perf_counter()

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self._events)

    def emit(self, ev: str, step: Optional[float] = None, **fields) -> None:
        """Append one event (dropping the oldest when the ring is full)."""
        if len(self._events) >= self.capacity:
            self._events.popleft()
            self.dropped += 1
        e = {"ev": ev,
             "step": float(self.step if step is None else step),
             "t": time.perf_counter() - self._t0}
        e.update(fields)
        self._events.append(e)

    @property
    def events(self) -> List[dict]:
        return list(self._events)

    def dump_jsonl(self, path: str) -> None:
        """Write the trace as JSONL: a ``trace_meta`` header line (event
        count, drops, capacity) followed by one event per line."""
        with open(path, "w") as f:
            f.write(json.dumps({"ev": "trace_meta", "step": 0.0, "t": 0.0,
                                "events": len(self._events),
                                "dropped": self.dropped,
                                "capacity": self.capacity}) + "\n")
            for e in self._events:
                f.write(json.dumps(e) + "\n")


def read_trace(path: str) -> Tuple[List[dict], bool]:
    """Read a JSONL trace back into event dicts, tolerating a truncated
    FINAL line — the artifact a crash mid-``dump_jsonl`` leaves behind,
    exactly the situation a post-mortem reader must survive. Returns
    ``(events, truncated)``; a malformed line anywhere *else* still
    raises (that is corruption, not truncation)."""
    with open(path) as f:
        lines = [ln.strip() for ln in f]
    while lines and not lines[-1]:
        lines.pop()
    events, truncated = [], False
    for i, line in enumerate(lines):
        if not line:
            continue
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                truncated = True
            else:
                raise
    return events, truncated


def load_trace(path: str) -> List[dict]:
    """Read a JSONL trace back into a list of event dicts (the
    ``trace_meta`` header, when present, stays at index 0). A truncated
    final line — crash mid-dump — is silently dropped; use ``read_trace``
    to observe the truncation flag."""
    return read_trace(path)[0]


def validate_events(events, schema: Dict[str, FrozenSet[str]] = EVENT_SCHEMA,
                    ) -> List[str]:
    """Schema check: every event's type must be known and its payload field
    set must match the schema EXACTLY. Returns human-readable violations
    (empty = conformant) — the golden-trace test and ``trace_report
    --validate`` both run this."""
    problems = []
    for i, e in enumerate(events):
        ev = e.get("ev")
        if ev not in schema:
            problems.append(f"event {i}: unknown type {ev!r}")
            continue
        missing = {"ev", "step", "t"} - set(e)
        if missing:
            problems.append(f"event {i} ({ev}): missing base fields "
                            f"{sorted(missing)}")
        payload = frozenset(set(e) - {"ev", "step", "t"})
        if payload != schema[ev]:
            extra = sorted(payload - schema[ev])
            absent = sorted(schema[ev] - payload)
            problems.append(f"event {i} ({ev}): payload mismatch "
                            f"(extra={extra}, missing={absent})")
    return problems
