"""Metrics registry: counters, gauges, histograms, and boundary-sampled
time series.

The always-on half of the observability layer: ``ServeStats`` is built
from a per-run ``MetricsRegistry`` (counters for steps/dispatches/syncs,
gauges sampled into time series at horizon boundaries, histograms for
latency distributions), so queue-depth and occupancy summaries exist even
with event tracing off. The registry is plain Python over plain floats —
no jax, no locks (the engine loop is single-threaded) — so the hot-path
cost of a counter bump is one dict-free attribute add.

``Histogram.percentile`` implements the same linear-interpolation rule as
``numpy.percentile``'s default, pinned by ``tests/test_obs.py`` against
numpy itself.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple


class Counter:
    """Monotonic accumulator (float: wall-second totals share the type)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-value (or high-watermark, via ``hi``) instantaneous metric."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def hi(self, v: float) -> None:
        """High-watermark update: keep the max ever seen."""
        if v > self.value:
            self.value = float(v)


class Histogram:
    """Value distribution with exact percentiles.

    Stores raw observations (bounded by ``max_samples`` with uniform
    stride-decimation on overflow: every second sample is dropped and the
    stride doubles, so the kept set stays an unbiased subsample of the
    stream) — serve runs observe at most a few values per request, so the
    exact path is the common one.
    """
    __slots__ = ("name", "values", "count", "total", "vmin", "vmax",
                 "max_samples", "_stride", "_skip")

    def __init__(self, name: str, max_samples: int = 65536):
        self.name = name
        self.values: List[float] = []
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.max_samples = int(max_samples)
        self._stride = 1
        self._skip = 0

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if self._skip:
            self._skip -= 1
            return
        self._skip = self._stride - 1
        if len(self.values) >= self.max_samples:
            self.values = self.values[::2]
            self._stride *= 2
            self._skip = self._stride - 1
        self.values.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Linear-interpolated percentile (numpy.percentile's default
        method) over the retained samples; 0.0 when empty."""
        if not self.values:
            return 0.0
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q={q} outside [0, 100]")
        xs = sorted(self.values)
        pos = (len(xs) - 1) * q / 100.0
        lo = math.floor(pos)
        hi = math.ceil(pos)
        if lo == hi:
            return xs[int(pos)]
        return xs[lo] * (hi - pos) + xs[hi] * (pos - lo)

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Named counters/gauges/histograms plus boundary-sampled series.

    ``sample(step)`` snapshots every gauge AND counter into its time
    series (``series[name]`` is a list of ``(step, value)``), which is
    what turns instantaneous pool state into the occupancy / queue-depth
    timelines the stats summarize and ``trace_report`` plots.
    """

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.series: Dict[str, List[Tuple[float, float]]] = {}

    # -- get-or-create handles ------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name)
        return h

    # -- convenience mutators -------------------------------------------------
    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def hi(self, name: str, v: float) -> None:
        self.gauge(name).hi(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).record(v)

    def value(self, name: str, default: float = 0.0) -> float:
        """Current value of a counter or gauge (counters win a name tie)."""
        if name in self.counters:
            return self.counters[name].value
        if name in self.gauges:
            return self.gauges[name].value
        return default

    # -- time series ----------------------------------------------------------
    def sample(self, step: float) -> None:
        """Snapshot every gauge and counter into its series at ``step``."""
        for name, g in self.gauges.items():
            self.series.setdefault(name, []).append((float(step), g.value))
        for name, c in self.counters.items():
            self.series.setdefault(name, []).append((float(step), c.value))

    def series_stats(self, name: str) -> Tuple[float, float]:
        """(mean, max) over a sampled series; falls back to the live
        gauge/counter value when the series is empty (a run too short to
        hit a sampling boundary still reports its last state)."""
        pts = self.series.get(name)
        if not pts:
            v = self.value(name)
            return v, v
        vals = [v for _, v in pts]
        return sum(vals) / len(vals), max(vals)

    def summary(self) -> dict:
        """One JSON-able dict of everything: counter/gauge values,
        histogram summaries, and series lengths."""
        return {
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: g.value for k, g in self.gauges.items()},
            "histograms": {k: h.summary()
                           for k, h in self.histograms.items()},
            "series": {k: len(v) for k, v in self.series.items()},
        }


class RunObs:
    """Per-run observability context: the metrics registry every run keeps
    (ServeStats is built from it) plus the — possibly null — event tracer.
    The engine threads one of these through its loop where the old plain
    counters dict used to travel."""
    __slots__ = ("metrics", "tracer", "block_report", "boundaries")

    def __init__(self, tracer=None):
        from repro.obs.events import NULL_TRACER
        self.metrics = MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.block_report: Optional[dict] = None
        self.boundaries = 0     # decode boundaries seen (sampling cadence)

    # counter shorthands (the engine's hot-path spellings)
    def inc(self, name: str, n: float = 1.0) -> None:
        self.metrics.inc(name, n)

    def hi(self, name: str, v: float) -> None:
        self.metrics.hi(name, v)

    def value(self, name: str, default: float = 0.0) -> float:
        return self.metrics.value(name, default)
