"""Chrome trace-event export: render a serve trace for Perfetto.

Converts the tracer's flat event list into the Chrome Trace Event JSON
format (https://ui.perfetto.dev loads it directly, as does
chrome://tracing): span events (``prefill``, ``prefill_round``,
``decode_horizon``) become complete ("X") events with real durations on
per-phase tracks, instantaneous scheduler/pool decisions become instant
("i") events on their own tracks, and every event carries its payload —
tenant, request id, K, width — as ``args`` so the Perfetto query engine
can slice by them.

Track layout (one process, one thread per phase):

    tid 0  scheduler   admit / evict / preempt / budget_skip / defer
    tid 1  prefill     prefill + prefill_round spans
    tid 2  decode      decode_horizon spans (+ horizon_shrink instants)
    tid 3  pool        block_alloc / block_grow / block_free / prefix_evict
    tid 4  profile     dispatch_profile — utilization counter ("C") tracks
                       per phase, compile dispatches as instants
    tid 5  chaos       fault_inject / recover instants

``dispatch_profile`` events (obs/prof.py) render as Chrome COUNTER events:
one ``util[<phase>]`` counter track per phase carrying the
measured-vs-roofline utilization ratio over time, so Perfetto plots the
utilization curve directly under the span tracks. Compile dispatches (no
meaningful utilization) render as instants named ``compile[<sig>]``.
"""
from __future__ import annotations

import json
from typing import Iterable, List

from repro.obs.events import SPAN_EVENTS

#: event type -> (tid, track name)
_TRACKS = {
    "admit": (0, "scheduler"), "evict": (0, "scheduler"),
    "preempt": (0, "scheduler"), "budget_skip": (0, "scheduler"),
    "defer": (0, "scheduler"), "run_start": (0, "scheduler"),
    "run_end": (0, "scheduler"),
    "prefill": (1, "prefill"), "prefill_round": (1, "prefill"),
    "decode_horizon": (2, "decode"), "horizon_shrink": (2, "decode"),
    "block_alloc": (3, "pool"), "block_grow": (3, "pool"),
    "block_free": (3, "pool"), "prefix_evict": (3, "pool"),
    "dispatch_profile": (4, "profile"),
    "fault_inject": (5, "chaos"), "recover": (5, "chaos"),
    "scale_up": (6, "elastic"), "scale_down": (6, "elastic"),
    "migrate": (6, "elastic"),
}


def _name(e: dict) -> str:
    """Display name: the type, decorated with the span's shape so a glance
    at the track reads the dispatch geometry."""
    ev = e["ev"]
    if ev == "decode_horizon":
        return f"decode[K={e.get('k')},W={e.get('width')}]"
    if ev == "prefill_round":
        return f"prefill_round[{e.get('lanes')}/{e.get('width')}]"
    if ev == "prefill":
        return f"prefill[req={e.get('req')}]"
    if ev == "fault_inject":
        return f"fault[{e.get('kind')}]"
    if ev == "recover":
        return f"recover[{e.get('kind')}:{e.get('action')}]"
    if ev in ("scale_up", "scale_down"):
        return f"{ev}[{e.get('reason')}:{e.get('units')}]"
    if ev == "migrate":
        return f"migrate[{e.get('blocks')}+{e.get('added')}]"
    return ev


def to_chrome_trace(events: Iterable[dict]) -> dict:
    """Event list -> Chrome trace object ({"traceEvents": [...], ...})."""
    out: List[dict] = []
    pid = 0
    out.append({"ph": "M", "pid": pid, "name": "process_name",
                "args": {"name": "repro.serve"}})
    for tid, label in sorted({v for v in _TRACKS.values()}):
        out.append({"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                    "args": {"name": label}})
    for e in events:
        ev = e.get("ev")
        if ev == "trace_meta":
            continue
        tid = _TRACKS.get(ev, (0, "scheduler"))[0]
        args = {k: v for k, v in e.items() if k not in ("ev", "t")}
        t_us = float(e.get("t", 0.0)) * 1e6
        if ev == "dispatch_profile":
            if e.get("compile"):
                out.append({"ph": "i", "pid": pid, "tid": tid,
                            "name": f"compile[{e.get('sig')}]",
                            "ts": t_us, "s": "t", "args": args})
            else:
                out.append({"ph": "C", "pid": pid, "tid": tid,
                            "name": f"util[{e.get('phase')}]", "ts": t_us,
                            "args": {"util": float(e.get("util") or 0.0)}})
        elif ev in SPAN_EVENTS:
            dur_us = max(float(e.get("dur_s") or 0.0) * 1e6, 1.0)
            # the tracer stamps t at emit time (span END); Chrome wants the
            # start timestamp.
            out.append({"ph": "X", "pid": pid, "tid": tid, "name": _name(e),
                        "ts": max(t_us - dur_us, 0.0), "dur": dur_us,
                        "args": args})
        else:
            out.append({"ph": "i", "pid": pid, "tid": tid, "name": _name(e),
                        "ts": t_us, "s": "t", "args": args})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, events: Iterable[dict]) -> None:
    """Write a Perfetto-loadable Chrome trace JSON file."""
    with open(path, "w") as f:
        json.dump(to_chrome_trace(events), f)
