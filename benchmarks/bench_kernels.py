"""Substrate kernels — wall time of the Pallas kernels (interpret mode on
CPU; compiled Mosaic on TPU) vs the pure-jnp references, plus allclose."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    ks = jax.random.split(jax.random.key(0), 4)

    b, s, hq, hkv, d = 1, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    t_k = _time(lambda *a: ops.flash_attention(*a, causal=True), q, k, v)
    t_r = _time(lambda *a: ref.attention(*a, causal=True), q, k, v)
    err = float(jnp.abs(ops.flash_attention(q, k, v, causal=True)
                        - ref.attention(q, k, v, causal=True)).max())
    rows.append({"name": "kernel/flash_attention", "us_per_call": t_k,
                 "derived": f"ref_us={t_r:.0f} max_err={err:.2e}"})

    b, s, h, p, n = 1, 256, 4, 64, 32
    xdt = jax.random.normal(ks[0], (b, s, h, p))
    a_log = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    B = jax.random.normal(ks[2], (b, s, h, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s, h, n)) * 0.5
    t_k = _time(lambda *a: ops.ssd_scan(*a, chunk=64), xdt, a_log, B, C)
    t_r = _time(ref.ssd, xdt, a_log, B, C)
    err = float(jnp.abs(ops.ssd_scan(xdt, a_log, B, C, chunk=64)
                        - ref.ssd(xdt, a_log, B, C)).max())
    rows.append({"name": "kernel/ssd_scan", "us_per_call": t_k,
                 "derived": f"ref_us={t_r:.0f} max_err={err:.2e}"})

    g, c, kk, nn = 8, 128, 256, 128
    x = jax.random.normal(ks[0], (g, c, kk))
    w = jax.random.normal(ks[1], (g, kk, nn))
    t_k = _time(ops.grouped_matmul, x, w)
    t_r = _time(ref.grouped_matmul, x, w)
    err = float(jnp.abs(ops.grouped_matmul(x, w) - ref.grouped_matmul(x, w)).max())
    rows.append({"name": "kernel/grouped_matmul", "us_per_call": t_k,
                 "derived": f"ref_us={t_r:.0f} max_err={err:.2e}"})
    return rows
