"""Fig. 7 / Fig. 8 — LAS and SRTF on multi-GPU traces vs load (128 GPUs):
avg JCT for proportional vs TUNE vs (paper) within-10%-of-OPT."""
from __future__ import annotations

import time

from benchmarks.common import FAST, run_policies, speedup
from repro.core.trace import TraceConfig, generate


def run():
    rows = []
    loads = (7.0,) if FAST else (4.0, 6.0, 8.0)
    for pol in ("las", "srtf", "ftf"):
        for load in loads:
            jobs = generate(TraceConfig(n_jobs=700 if FAST else 1600,
                                        split=(20, 70, 10), arrival="poisson",
                                        jobs_per_hour=load, multi_gpu=True,
                                        seed=11))
            t0 = time.perf_counter()
            sub = run_policies(jobs, 16, [pol], ["proportional", "tune"],
                               steady_skip=250, steady_count=300)
            sp = speedup(sub, pol)
            p99_sp = speedup(sub, pol, metric="p99_jct_h")
            rows.append({
                "name": f"fig7_8/{pol}_{load:.0f}jobs_hr",
                "us_per_call": (time.perf_counter() - t0) * 1e6,
                "derived": f"avg_speedup={sp:.2f}x p99_speedup={p99_sp:.2f}x",
                "speedup": sp,
            })
    return rows
