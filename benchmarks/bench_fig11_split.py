"""Fig. 11 — impact of workload split (FIFO, multi-GPU): GREEDY breaks down
as the resource-sensitive share grows; TUNE never drops below proportional."""
from __future__ import annotations

import time

from benchmarks.common import FAST, run_policies
from repro.core.trace import TraceConfig, generate


def run():
    rows = []
    splits = ((20, 70, 10), (50, 0, 50), (70, 0, 30))
    load = 4.0 if FAST else 5.0
    n_jobs = 700 if FAST else 1400
    for split in splits:
        jobs = generate(TraceConfig(n_jobs=n_jobs, split=split,
                                    arrival="poisson", jobs_per_hour=load,
                                    multi_gpu=True, seed=17))
        t0 = time.perf_counter()
        sub = run_policies(jobs, 16, ["fifo"],
                           ["proportional", "greedy", "tune"],
                           steady_skip=250, steady_count=300)
        vals = {r["allocator"]: r["avg_jct_h"] for r in sub}
        rows.append({
            "name": f"fig11_split/{split[0]}-{split[1]}-{split[2]}",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "derived": (f"prop={vals['proportional']:.1f}h greedy={vals['greedy']:.1f}h "
                        f"tune={vals['tune']:.1f}h "
                        f"tune_not_worse={vals['tune'] <= vals['proportional'] * 1.05}"),
            "vals": vals,
        })
    return rows
