"""Fig. 10 — cluster resource utilization: GREEDY under-utilizes GPUs at a
resource-heavy split; TUNE sustains ~full GPU allocation and raises CPU
utilization over GPU-proportional."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, run_policies
from repro.core.trace import TraceConfig, generate


def run():
    jobs = generate(TraceConfig(n_jobs=300 if FAST else 800, split=(70, 0, 30),
                                arrival="poisson", jobs_per_hour=5.5,
                                multi_gpu=True, seed=13))
    t0 = time.perf_counter()
    sub = run_policies(jobs, 16, ["fifo"], ["proportional", "greedy", "tune"],
                       steady_skip=60, steady_count=180)
    rows = []
    for r in sub:
        res = r["result"]
        sat = [i for i, q in enumerate(res.queue_len_samples) if q > 0]
        idx = sat if sat else range(len(res.util_samples))
        gpu = np.mean([res.util_samples[i]["gpu"] for i in idx])
        cpu = np.mean([res.util_samples[i]["cpu"] for i in idx])
        rows.append({
            "name": f"fig10_util/{r['allocator']}",
            "us_per_call": (time.perf_counter() - t0) * 1e6 / 3,
            "derived": f"gpu_util={gpu * 100:.0f}% cpu_util={cpu * 100:.0f}%",
            "gpu_util": float(gpu), "cpu_util": float(cpu),
        })
    return rows
