"""Fig. 6 / Table 6 — Philly trace on a 512-GPU cluster (64 servers),
split (20,70,10): avg JCT for SRTF/LAS/FIFO, per-job speedup distribution,
and the short/long-job breakdown under SRTF."""
from __future__ import annotations

import copy
import time

import numpy as np

from benchmarks.common import FAST, run_policies
from repro.core.trace import philly_trace


def run():
    rows = []
    n_jobs = 1600 if FAST else 8000
    load = 42.0 if FAST else 64.0
    jobs = philly_trace(n_jobs=n_jobs, split=(20, 70, 10), seed=7,
                        jobs_per_hour=load)
    policies = ["srtf"] if FAST else ["srtf", "las", "fifo"]
    for pol in policies:
        t0 = time.perf_counter()
        sub = run_policies(jobs, 64, [pol], ["proportional", "tune"],
                           steady_skip=500, steady_count=600)
        prop = next(r for r in sub if r["allocator"] == "proportional")
        tune = next(r for r in sub if r["allocator"] == "tune")
        # per-job speedups (matched by job id)
        pj = {j.job_id: j.jct() for j in prop["result"].jobs if j.jct()}
        tj = {j.job_id: j.jct() for j in tune["result"].jobs if j.jct()}
        sp = np.array([pj[i] / tj[i] for i in set(pj) & set(tj)])
        # short/long split under this policy (short: JCT < 4h in baseline)
        short = [i for i in set(pj) & set(tj) if pj[i] < 4 * 3600]
        long_ = [i for i in set(pj) & set(tj) if pj[i] >= 4 * 3600]
        s_sp = (np.mean([pj[i] for i in short]) / np.mean([tj[i] for i in short])
                if short else float("nan"))
        l_sp = (np.mean([pj[i] for i in long_]) / np.mean([tj[i] for i in long_])
                if long_ else float("nan"))
        rows.append({
            "name": f"fig6_philly/{pol}",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "derived": (f"prop={prop['avg_jct_h']:.1f}h tune={tune['avg_jct_h']:.1f}h "
                        f"speedup={prop['avg_jct_h'] / tune['avg_jct_h']:.2f}x "
                        f"max_job_speedup={sp.max():.1f}x "
                        f"short={s_sp:.2f}x long={l_sp:.2f}x"),
            "speedup": prop["avg_jct_h"] / tune["avg_jct_h"],
            "max_job_speedup": float(sp.max()),
        })
    return rows
