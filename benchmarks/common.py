"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import copy
import os
import time
from typing import Dict, List

import numpy as np

from repro.core.simulator import simulate
from repro.core.trace import TraceConfig, generate

FAST = os.environ.get("BENCH_FULL", "") == ""     # default: fast mode


def run_policies(jobs, n_servers, policies, allocators, *, spec=None,
                 steady_skip=0, steady_count=0, round_seconds=300.0,
                 max_hours=24_000.0) -> List[Dict]:
    """Cross product of policies x allocators on deep-copied jobs."""
    rows = []
    for pol in policies:
        for alloc in allocators:
            t0 = time.perf_counter()
            kw = dict(policy=pol, allocator=alloc,
                      steady_skip=steady_skip, steady_count=steady_count,
                      round_seconds=round_seconds, max_hours=max_hours)
            if spec is not None:
                kw["spec"] = spec
            res = simulate(n_servers, copy.deepcopy(jobs), **kw)
            rows.append({
                "policy": pol, "allocator": alloc,
                "avg_jct_h": res.avg_jct / 3600.0,
                "p99_jct_h": res.p99_jct / 3600.0,
                "makespan_h": res.makespan / 3600.0,
                "rounds": res.rounds,
                "wall_s": time.perf_counter() - t0,
                "result": res,
            })
    return rows


def speedup(rows, policy, base="proportional", other="tune",
            metric="avg_jct_h") -> float:
    b = next(r for r in rows if r["policy"] == policy and r["allocator"] == base)
    o = next(r for r in rows if r["policy"] == policy and r["allocator"] == other)
    return b[metric] / o[metric]


def jct_cdf(result, skip=0, count=0) -> np.ndarray:
    jobs = result.monitored(skip, count)
    return np.sort([j.jct() / 3600.0 for j in jobs if j.jct() is not None])
