"""Serve engines — static vs continuous vs sharded-continuous tokens/s for an
attention-family and an ssm-family architecture, plus paged-vs-contiguous
admission density at mixed prompt lengths, a shared-prefix (prefix-cache)
workload, and a decode-horizon K=1 vs K=8 ablation (smoke shapes; set
BENCH_FULL=1 for a larger request set). Rows measure the *second* run of
each engine (``_run_warm``): cold runs are compile-dominated at smoke
shapes and would bury the decode hot path.

Every row splits the blended us_per_call into prefill/decode wall time and
reports the jitted-dispatch counts (``disp=P+D``), host sync points
(``hs``), the decode horizon (``K``), and the prefix-cache hit rate, so the
trajectory captures where each engine spends its time. Rows also carry
structured ``decode_ms_per_tok`` / ``decode_dispatches`` / ``host_syncs``
fields that ``benchmarks.run --check`` gates against the recorded
baseline."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import FAST
from repro.configs import get_config
from repro.serve import (ServeEngine, ServeRequest, Tenant, TenantRegistry,
                         plan_allocation, profiles_from_requests,
                         sharded_engine)

ARCHS = ("qwen2-0.5b", "mamba2-780m")


def _run_warm(engine, mk_requests):
    """Steady-state measurement: run once to compile every (width, horizon)
    program, then measure a second run on fresh request copies. Cold runs
    are compile-dominated at smoke shapes, which buries the decode hot path
    the trajectory (and the --check gate) cares about."""
    engine.run(mk_requests())
    return engine.run(mk_requests())


def _requests(cfg, n, max_new, seed=0, stagger=False):
    """Mixed-length request set. ``stagger`` additionally mixes the
    generation budgets so completions spread over the run — mid-run
    evictions are what exercise live-slot compaction (a uniform budget
    finishes every row on the same step and saves nothing)."""
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        rng.integers(1, cfg.vocab_size,
                     size=int(rng.integers(4, 12))).astype(np.int32),
        max_new_tokens=(int(rng.integers(max(2, max_new // 4), max_new + 1))
                        if stagger else max_new),
        arrival_time=i / 2.0)
        for i in range(n)]


def _row(name, stats):
    us = 1e6 * stats.wall_s / max(stats.new_tokens, 1)
    return {"name": name, "us_per_call": us,
            # structured fields for the `benchmarks.run --check` regression
            # gate: decode wall per generated token (machine-speed bound,
            # generous tolerance) and dispatch/sync counts (deterministic).
            "decode_ms_per_tok": 1e3 * stats.decode_s
                                 / max(stats.new_tokens, 1),
            "decode_dispatches": stats.decode_dispatches,
            "host_syncs": stats.host_syncs,
            "derived": (f"tok_s={stats.tokens_per_s:.1f} "
                        f"util={stats.slot_utilization:.2f} "
                        f"lat_steps={stats.mean_latency_steps:.1f} "
                        f"prefill_ms={stats.prefill_s * 1e3:.0f} "
                        f"decode_ms={stats.decode_s * 1e3:.0f} "
                        f"disp={stats.prefill_dispatches}"
                        f"+{stats.decode_dispatches} "
                        f"hs={stats.host_syncs} "
                        f"K={stats.decode_horizon} "
                        f"hit={stats.prefix_hit_rate:.2f}")}


def run():
    n, max_new = (8, 8) if FAST else (32, 32)
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)

        def static_reqs():
            reqs = _requests(cfg, n, max_new)
            for r in reqs:
                r.arrival_time = 0.0
            return reqs

        static = ServeEngine(cfg, max_len=64)
        _, st = _run_warm(static, static_reqs)
        rows.append(_row(f"serve/static/{arch}", st))

        cont = ServeEngine(cfg, max_len=64, n_slots=max(2, n // 2),
                           policy="fcfs")
        _, st = _run_warm(cont, lambda: _requests(cfg, n, max_new))
        rows.append(_row(f"serve/continuous/{arch}", st))

        shard = sharded_engine(cfg, n_slots=max(2, n // 2), max_len=64)
        _, st = _run_warm(shard, lambda: _requests(cfg, n, max_new))
        row = _row(f"serve/sharded-continuous/{arch}", st)
        row["derived"] += f" ndev={jax.device_count()}"
        rows.append(row)
    rows.extend(_paged_admission_rows(n, max_new))
    rows.extend(_prefix_cache_rows(n, max_new))
    rows.extend(_horizon_rows(n, max_new))
    rows.extend(_tenant_rows())
    rows.extend(_obs_rows(n, max_new))
    rows.extend(_profiled_rows(n, max_new))
    rows.extend(_chaos_rows(n))
    rows.extend(_elastic_rows(n))
    return rows


def _chaos_rows(n):
    """Faulted vs fault-free Philly replay at EQUAL pool budget: the same
    open-loop request set (``serve.replay.philly_requests``) through the
    same paged engine, once clean and once under a seeded 3-fault schedule
    (slot kill, prefix flush, pool shrink + restore). The chaos row's
    ``recovery_s`` is the wall-clock the recovery paths cost on top of the
    clean run; its gated ``dropped`` field holds the drop count at the
    recorded baseline (0 — this schedule must stay survivable without
    giving up work) and both rows gate ``slo_attainment`` over the scored
    set as a floor. Outputs stay token-identical to the clean run for
    every non-dropped request (tests/test_chaos.py pins that); the warm
    measured run replays the identical schedule (``FaultInjector.reset``
    re-arms per run)."""
    from repro.serve import FaultInjector, FaultSchedule, philly_requests

    arch = "qwen2-0.5b"
    cfg = get_config(arch, smoke=True)
    max_len, block, n_blocks = 64, 8, 24

    def reqs():
        return philly_requests(cfg.vocab_size, n, load=2.0, seed=7,
                               prompt_len=12, max_new=8, max_len=max_len)

    spec = "slot_kill@2,prefix_flush@4,pool_shrink@6:blocks=6:restore_after=6"
    rows, walls = [], {}
    for label, injector in (
            ("replay-clean", None),
            ("replay-chaos", FaultInjector(FaultSchedule.from_spec(spec)))):
        eng = ServeEngine(cfg, max_len=max_len, n_slots=max(2, n // 2),
                          cache="paged", block_size=block, n_blocks=n_blocks,
                          injector=injector)
        _, st = _run_warm(eng, reqs)
        eng.pool.audit()
        walls[label] = st.wall_s
        row = _row(f"serve/{label}/{arch}", st)
        row["dropped"] = st.dropped
        row["slo_attainment"] = st.slo_attainment
        row["derived"] += (f" faults={st.faults_injected} "
                           f"rec={st.recoveries} drop={st.dropped} "
                           f"att={st.slo_attainment:.2f}")
        if label == "replay-chaos":
            row["derived"] += (f" recovery_s="
                               f"{st.wall_s - walls['replay-clean']:.3f}")
        rows.append(row)
    return rows


def _elastic_rows(n):
    """Elastic recovery value, at EQUAL fault budget: the same Philly
    request set through the same paged engine under the same
    ``device_fail`` (the pool revoked down to its one-block floor, mesh
    narrowed) —
    once with the scheduled ``device_join`` recovery (the pool and
    bucketing restore mid-run, parked requests admit, nothing drops) and
    once with the failure left standing (requests burn their admission
    retries against a pool that will never fit them and drop).
    Gated fields: ``dropped`` (0 with recovery — the hold-don't-drop
    admission contract) and ``slo_attainment`` over the scored set. The
    in-module assertion pins the headline: recovery must strictly beat
    no-recovery on tokens/s, else the reshape machinery is costing more
    than the capacity it returns."""
    from repro.serve import FaultInjector, FaultSchedule, philly_requests

    arch = "qwen2-0.5b"
    cfg = get_config(arch, smoke=True)
    max_len, block, n_blocks = 64, 8, 24

    def reqs():
        return philly_requests(cfg.vocab_size, n, load=1.0, seed=7,
                               prompt_len=12, max_new=12, max_len=max_len)

    fail = "device_fail@2:blocks=23"
    rows, tok_s = [], {}
    for label, spec in (("elastic-recovery", fail + ":restore_after=4"),
                        ("elastic-norecovery", fail)):
        inj = FaultInjector(FaultSchedule.from_spec(spec))
        eng = ServeEngine(cfg, max_len=max_len, n_slots=max(2, n // 2),
                          cache="paged", block_size=block, n_blocks=n_blocks,
                          injector=inj, max_admit_retries=2)
        _, st = _run_warm(eng, reqs)
        eng.pool.audit()
        tok_s[label] = st.tokens_per_s
        row = _row(f"serve/{label}/{arch}", st)
        row["dropped"] = st.dropped
        row["slo_attainment"] = st.slo_attainment
        row["derived"] += (f" ups={st.scale_ups} downs={st.scale_downs} "
                           f"drop={st.dropped} att={st.slo_attainment:.2f}")
        rows.append(row)
        if label == "elastic-recovery":
            assert st.dropped == 0, \
                f"recovery run dropped {st.dropped} requests"
            assert st.scale_ups == 1 and st.scale_downs == 1, st
    assert tok_s["elastic-recovery"] > tok_s["elastic-norecovery"], \
        (f"recovery must beat no-recovery: "
         f"{tok_s['elastic-recovery']:.2f} <= "
         f"{tok_s['elastic-norecovery']:.2f} tok/s")
    return rows


def _obs_rows(n, max_new):
    """Event tracing cost, as a gated row: the staggered paged workload
    with a full ``obs.Tracer`` attached. Its ``decode_ms_per_tok`` bound
    keeps tracing-ON overhead inside the normal tolerance band, while the
    tracing-OFF contract — hooks compiling down to one falsy branch — is
    bounded by every OTHER serve row in this module, which all run with
    the default NullTracer against the same recorded baseline."""
    arch = "qwen2-0.5b"
    cfg = get_config(arch, smoke=True)
    from repro.obs import Tracer
    eng = ServeEngine(cfg, max_len=64, n_slots=max(2, n // 2), cache="paged",
                      block_size=8, tracer=Tracer())
    _, st = _run_warm(eng, lambda: _requests(cfg, n, max_new, stagger=True))
    row = _row(f"serve/obs-traced/{arch}", st)
    row["derived"] += (f" events={len(eng.tracer)} "
                       f"qd={st.mean_queue_depth:.1f} "
                       f"occ={st.mean_occupancy:.2f}")
    return [row]


def _profiled_rows(n, max_new):
    """Dispatch-profiling cost, as a gated row: the same staggered paged
    workload as ``_obs_rows`` with a tracer AND an ``obs.DispatchProfiler``
    attached — every hook site pays its profiling branch, the roofline
    arithmetic, and the ``dispatch_profile`` event emit. The row's
    ``decode_ms_per_tok`` bound keeps profiling-ON overhead inside the
    normal ``--check`` tolerance band (profiling-OFF is bounded by every
    other serve row, which all hold the falsy ``NULL_PROFILER``)."""
    arch = "qwen2-0.5b"
    cfg = get_config(arch, smoke=True)
    from repro.obs import DispatchProfiler, Tracer
    prof = DispatchProfiler(cfg)
    eng = ServeEngine(cfg, max_len=64, n_slots=max(2, n // 2), cache="paged",
                      block_size=8, tracer=Tracer(), profiler=prof)
    _, st = _run_warm(eng, lambda: _requests(cfg, n, max_new, stagger=True))
    row = _row(f"serve/obs-profiled/{arch}", st)
    s = prof.summary()
    dec = s["phases"].get("decode", {})
    row["derived"] += (f" sigs={s['signatures']} "
                       f"prof_disp={s['dispatches']} "
                       f"compiles={dec.get('compiles', 0)} "
                       f"util={st.decode_util:.2e}")
    return [row]


def _tenant_rows():
    """Two-tenant SLO scenario at EQUAL pool/lane budget: a batch tenant
    floods the block pool at step 0 (long prompts, long budgets, no SLO)
    while a latency tenant trickles short requests in under a tight
    step-clock SLO. The ``tenant-prop`` row is the capacity-proportional
    baseline — FCFS admission, no budgets, the SLOs only SCORED — and the
    ``tenant-slo`` row turns on the Synergy-on-serve mechanisms: SLO-slack
    admission ordering plus the optimistic profiler's planned per-tenant
    block/lane/horizon budgets. The latency tenant's p99 latency (decode
    steps — deterministic, so gate-able across machines) and SLO
    attainment are the rows' structured fields; the gate holds attainment
    as a floor and p99 as a ceiling. Outputs stay token-identical either
    way (tests/test_tenant.py pins that); only WHEN each request runs
    moves."""
    arch = "qwen2-0.5b"
    cfg = get_config(arch, smoke=True)
    max_len, block = 64, 8
    n_blocks, n_slots, lanes, k = 12, 6, 2, 8
    registry = TenantRegistry([
        Tenant("lat", weight=2.0, slo_steps=12.0),
        Tenant("batch", weight=1.0)])

    def reqs():
        rng = np.random.default_rng(7)
        out = [ServeRequest(
            rng.integers(1, cfg.vocab_size, size=16).astype(np.int32),
            max_new_tokens=16, arrival_time=0.0, tenant="batch")
            for _ in range(4)]
        out += [ServeRequest(
            rng.integers(1, cfg.vocab_size, size=4).astype(np.int32),
            max_new_tokens=4, arrival_time=2.0 + 4.0 * i, tenant="lat")
            for i in range(4)]
        return out

    def units_for(r):
        return -(-(len(r.prompt) + r.max_new_tokens) // block)

    profiles = profiles_from_requests(registry, reqs(), total_units=n_blocks,
                                      units_for=units_for, max_k=k)
    allocation = plan_allocation(registry, profiles, n_blocks,
                                 total_lanes=lanes, max_k=k,
                                 watermark_units=1)

    rows = []
    for label, policy, alloc in (("tenant-prop", "fcfs", None),
                                 ("tenant-slo", "slo", allocation)):
        eng = ServeEngine(cfg, max_len=max_len, n_slots=n_slots,
                          cache="paged", block_size=block, n_blocks=n_blocks,
                          watermark=1.0 / n_blocks, prefill_lanes=lanes,
                          decode_horizon=k, policy=policy,
                          tenants=registry, allocation=alloc)
        _, st = _run_warm(eng, reqs)
        lat, bat = st.tenants["lat"], st.tenants["batch"]
        row = _row(f"serve/{label}/{arch}", st)
        row["slo_attainment"] = lat["slo_attainment"]
        row["p99_latency_steps"] = lat["p99_latency_steps"]
        row["derived"] += (f" lat_p99={lat['p99_latency_steps']:.1f} "
                           f"lat_slo={lat['slo_attainment']:.2f} "
                           f"batch_p99={bat['p99_latency_steps']:.1f} "
                           f"pre={st.preemptions}")
        rows.append(row)
    return rows


def _horizon_rows(n, max_new):
    """Decode-horizon ablation: the same continuous workload at K=1 (the
    classic per-token loop) vs K=8 (device-resident multi-step decode) on
    both cache backends — decode dispatches and host syncs should drop
    ~K-fold at identical outputs."""
    arch = "qwen2-0.5b"
    cfg = get_config(arch, smoke=True)
    rows = []
    for label, kw in (("contig", dict()),
                      ("paged", dict(cache="paged", block_size=8))):
        for k in (1, 8):
            eng = ServeEngine(cfg, max_len=64, n_slots=max(2, n // 2),
                              decode_horizon=k, **kw)
            _, st = _run_warm(
                eng, lambda: _requests(cfg, n, max_new, stagger=True))
            rows.append(_row(f"serve/horizon-K{k}-{label}/{arch}", st))
    return rows


def _paged_admission_rows(n, max_new):
    """Paged vs contiguous admission at mixed prompt lengths AND mixed
    generation budgets on EQUAL token budgets: the contiguous pool spends
    the budget as few max_len rows, the paged pool as length-proportional
    blocks — so paged admits the same request set wider (max_active) and
    finishes in fewer decode steps — and the staggered completions force
    mid-run evictions so both backends' live-slot compaction
    (``rows_saved``) does real work."""
    arch = "qwen2-0.5b"
    cfg = get_config(arch, smoke=True)
    max_len, block = 64, 8
    budget = (n // 2) * max_len                  # cache positions
    # double the generation budgets: completions must span multiple K=8
    # horizons (the bucket only shrinks at a horizon boundary), so the
    # rows_saved stat keeps exercising live-slot compaction.
    reqs = _requests(cfg, n, 2 * max_new, stagger=True)   # fresh copies
                                                 # below arrive at step 0
    def copies():
        return [ServeRequest(r.prompt.copy(),
                             max_new_tokens=r.max_new_tokens)
                for r in reqs]

    cont = ServeEngine(cfg, max_len=max_len, n_slots=budget // max_len)
    _, st = _run_warm(cont, copies)
    rows = []
    row = _row(f"serve/admission-contiguous/{arch}", st)
    row["derived"] += (f" max_active={st.max_active} steps={st.steps} "
                       f"rows_saved={st.decode_rows_saved:.2f}")
    rows.append(row)

    paged = ServeEngine(cfg, max_len=max_len, n_slots=n, cache="paged",
                        block_size=block, n_blocks=budget // block,
                        watermark=0.0)
    _, st = _run_warm(paged, copies)
    row = _row(f"serve/admission-paged/{arch}", st)
    row["derived"] += (f" max_active={st.max_active} steps={st.steps} "
                       f"rows_saved={st.decode_rows_saved:.2f} "
                       f"occ={st.block_report['occupancy']:.2f} "
                       f"frag={st.block_report['internal_fragmentation']:.2f}")
    rows.append(row)
    return rows


def _prefix_cache_rows(n, max_new):
    """Shared-prefix workload (system-prompt style): every prompt repeats
    the same 3-block prefix ahead of a unique tail. With the prefix cache
    on, every request after the first serves the shared blocks from cache
    (skipping their prefill compute); the cache-off row is the ablation."""
    arch = "qwen2-0.5b"
    cfg = get_config(arch, smoke=True)
    max_len, block = 64, 8
    rng = np.random.default_rng(3)
    prefix = rng.integers(1, cfg.vocab_size, size=3 * block).astype(np.int32)

    def reqs():
        r = np.random.default_rng(4)
        return [ServeRequest(
            np.concatenate([prefix, r.integers(1, cfg.vocab_size,
                                               size=4).astype(np.int32)]),
            max_new_tokens=max_new, arrival_time=i / 2.0)
            for i in range(n)]

    rows = []
    for label, cached in (("prefix-paged", True),
                          ("prefix-paged-nocache", False)):
        eng = ServeEngine(cfg, max_len=max_len, n_slots=n, cache="paged",
                          block_size=block, prefix_cache=cached)
        _, st = _run_warm(eng, reqs)
        rows.append(_row(f"serve/{label}/{arch}", st))
    return rows
