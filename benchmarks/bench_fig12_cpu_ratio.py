"""Fig. 12 — CPU:GPU ratio sweep (FIFO, single-GPU trace): a richer baseline
server narrows Synergy's gap but TUNE stays ahead (paper: 3.4x..1.8x)."""
from __future__ import annotations

import time

from benchmarks.common import FAST, run_policies, speedup
from repro.core.cluster import ServerSpec
from repro.core.trace import TraceConfig, generate


def run():
    rows = []
    ratios = (3, 6) if FAST else (3, 4, 5, 6)
    load = 9.0
    for ratio in ratios:
        spec = ServerSpec(gpus=8, cpus=8.0 * ratio, mem=500.0)
        jobs = generate(TraceConfig(n_jobs=900 if FAST else 2000,
                                    split=(20, 70, 10), arrival="poisson",
                                    jobs_per_hour=load, multi_gpu=False,
                                    seed=23))
        t0 = time.perf_counter()
        sub = run_policies(jobs, 16, ["fifo"], ["proportional", "tune"],
                           spec=spec, steady_skip=300, steady_count=400)
        sp = speedup(sub, "fifo")
        rows.append({
            "name": f"fig12_cpu_ratio/{ratio}",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "derived": f"speedup={sp:.2f}x",
            "speedup": sp,
        })
    return rows
