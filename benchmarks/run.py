"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per experiment) and writes
the full records to experiments/bench_results.json. Default is a fast
configuration (minutes); set BENCH_FULL=1 for paper-scale runs.

    PYTHONPATH=src python -m benchmarks.run [module-substring ...]
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

# Force a multi-device host platform BEFORE any benchmark module imports jax,
# so bench_serve's sharded-continuous rows measure a real (4, 2) mesh instead
# of a degenerate single-device one. No-op if jax is already imported or the
# flag is already set (REPRO_BENCH_DEVICES overrides the count).
from repro.launch._bootstrap import force_host_devices

force_host_devices(os.environ.get("REPRO_BENCH_DEVICES", "8"))

MODULES = [
    "bench_profiling",        # Fig 5
    "bench_fig1_load",        # Fig 1 / Fig 9
    "bench_fig7_8_policies",  # Fig 7, 8
    "bench_fig10_util",       # Fig 10
    "bench_fig11_split",      # Fig 11
    "bench_fig12_cpu_ratio",  # Fig 12
    "bench_fig13_bigdata",    # Fig 13
    "bench_fig6_philly",      # Fig 6 / Table 6
    "bench_opt_vs_tune",      # section 5.6
    "bench_kernels",          # substrate kernels
    "bench_serve",            # serve engines (static/continuous/sharded)
    "bench_table5_cluster",   # Table 5 (live runtime; slowest — last)
]


def main() -> None:
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    records = []
    print("name,us_per_call,derived")
    t_start = time.time()
    for mod_name in MODULES:
        if filters and not any(f in mod_name for f in filters):
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run()
        except Exception:
            print(f"{mod_name},0,ERROR")
            traceback.print_exc()
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.0f},\"{r['derived']}\"")
            records.append({k: v for k, v in r.items() if k != "result"})
        sys.stdout.flush()

    os.makedirs("experiments", exist_ok=True)
    # A filtered run updates its rows in place instead of clobbering the
    # other modules' records, so the trajectory file stays complete.
    if filters and os.path.exists("experiments/bench_results.json"):
        try:
            with open("experiments/bench_results.json") as f:
                prior = json.load(f)
        except (OSError, json.JSONDecodeError):
            prior = []
        fresh = {r["name"] for r in records}
        records = [r for r in prior if r.get("name") not in fresh] + records
    with open("experiments/bench_results.json", "w") as f:
        json.dump(records, f, indent=2, default=str)
    print(f"# total wall: {time.time() - t_start:.0f}s; "
          f"records -> experiments/bench_results.json")


if __name__ == "__main__":
    main()
