"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per experiment) and writes
the full records to experiments/bench_results.json. Default is a fast
configuration (minutes); set BENCH_FULL=1 for paper-scale runs.

    PYTHONPATH=src python -m benchmarks.run [module-substring ...]

``--check`` turns the run into a CI regression gate instead of a recorder:
fresh rows are compared against the records already in
experiments/bench_results.json — ``decode_ms_per_tok`` within
``--tolerance`` (default 2.5x, generous because CI machines differ from the
recording machine), the machine-independent ``decode_dispatches`` /
``host_syncs`` counts within 1.5x, and the tenant rows' step-clock
``p99_latency_steps`` (ceiling) / ``slo_attainment`` (floor, higher is
better) — and the baseline file is left untouched. A gate failure prints
ONE line per offending row naming every out-of-band field. Exit status 1
on any regression — including a baseline row predating a newly gated
field, a baseline row whose module ran without reproducing it, or a
module that errored outright.

    PYTHONPATH=src python -m benchmarks.run bench_serve --check
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

# Force a multi-device host platform BEFORE any benchmark module imports jax,
# so bench_serve's sharded-continuous rows measure a real (4, 2) mesh instead
# of a degenerate single-device one. No-op if jax is already imported or the
# flag is already set (REPRO_BENCH_DEVICES overrides the count).
from repro.launch._bootstrap import force_host_devices

force_host_devices(os.environ.get("REPRO_BENCH_DEVICES", "8"))

MODULES = [
    "bench_profiling",        # Fig 5
    "bench_fig1_load",        # Fig 1 / Fig 9
    "bench_fig7_8_policies",  # Fig 7, 8
    "bench_fig10_util",       # Fig 10
    "bench_fig11_split",      # Fig 11
    "bench_fig12_cpu_ratio",  # Fig 12
    "bench_fig13_bigdata",    # Fig 13
    "bench_fig6_philly",      # Fig 6 / Table 6
    "bench_opt_vs_tune",      # section 5.6
    "bench_kernels",          # substrate kernels
    "bench_serve",            # serve engines (static/continuous/sharded)
    "bench_table5_cluster",   # Table 5 (live runtime; slowest — last)
]


#: structured row fields the --check gate compares: {field: (tolerance
#: factor | None = use --tolerance, absolute slack, direction)}.
#: direction "max" fails when got > want * tol + slack (costs: lower is
#: better); "min" fails when got < want / tol - slack (scores: higher is
#: better). Wall-clock fields get a multiplicative band for machine speed
#: plus an absolute ms floor so micro-rows are not gated on scheduler
#: noise; dispatch/sync counts and the tenant rows' step-clock latency /
#: SLO-attainment fields are deterministic for a given configuration, so a
#: breached bound there is a real regression.
CHECK_FIELDS = {"decode_ms_per_tok": (None, 2.0, "max"),
                "decode_dispatches": (1.5, 0.0, "max"),
                "host_syncs": (1.5, 0.0, "max"),
                "p99_latency_steps": (1.25, 2.0, "max"),
                "slo_attainment": (1.0, 0.02, "min"),
                # chaos-replay rows: requests dropped by fault recovery
                # (deterministic for a schedule; baseline is 0 — the
                # recorded schedule must stay survivable without giving
                # up work, so any fresh drop is a regression).
                "dropped": (1.0, 0.0, "max")}


def _parse_args(argv):
    """(filters, check, tolerance): positional substrings filter modules;
    --check flips gate mode; --tolerance X (or --tolerance=X) scales the
    wall-clock bound."""
    filters, check, tolerance = [], False, 2.5
    i = 0
    try:
        while i < len(argv):
            a = argv[i]
            if a == "--check":
                check = True
            elif a == "--tolerance":
                tolerance = float(argv[i + 1])
                i += 1
            elif a.startswith("--tolerance="):
                tolerance = float(a.split("=", 1)[1])
            elif not a.startswith("-"):
                filters.append(a)
            i += 1
    except (IndexError, ValueError):
        raise SystemExit("usage: benchmarks.run [module-substring ...] "
                         "[--check] [--tolerance X]")
    return filters, check, tolerance


def _field_breaches(rec, ref, tolerance: float):
    """Every gated field of one (fresh, baseline) row pair that is out of
    band — ALL of them, not just the first, so one gate run names every
    problem a row has."""
    breaches = []
    for field, (tol, slack, direction) in CHECK_FIELDS.items():
        tol = tolerance if tol is None else tol
        got, want = rec.get(field), ref.get(field)
        if got is None and want is None:
            continue            # neither side carries it (non-tenant rows)
        if want is None:
            breaches.append(
                f"baseline predates field {field!r} — re-record it "
                f"(benchmarks.run without --check)")
            continue
        if got is None:
            breaches.append(
                f"fresh row dropped gated field {field!r} "
                f"(baseline has {float(want):.2f})")
            continue
        if direction == "min":
            bound = float(want) / tol - slack
            if float(got) < bound:
                breaches.append(
                    f"{field} {float(got):.2f} < {float(want):.2f} / "
                    f"{tol:g} - {slack:g}")
            continue
        # a zero baseline can't scale multiplicatively, but the absolute
        # slack still gates: a dropped=0 baseline breaches on ANY drop,
        # while wall-clock fields keep their ms floor.
        bound = float(want) * tol + slack
        if float(got) > bound:
            breaches.append(
                f"{field} {float(got):.2f} > {float(want):.2f} * "
                f"{tol:g} + {slack:g}")
    return breaches


def check_regressions(records, baseline, tolerance: float,
                      ran_modules=frozenset()):
    """Compare fresh rows against the recorded baseline; returns a list of
    human-readable regression strings (empty = gate passes), ONE per
    offending row, naming every out-of-band field of that row in one pass
    — a gate failure reads as the full repair list, not the first symptom.

    Rows absent from the baseline are skipped — the gate only tightens as
    the baseline file accumulates rows — but a gated FIELD carried by only
    one side of a shared row fails explicitly (a baseline row predating a
    newly added field must be re-recorded), and a BASELINE row whose
    module ran this pass without reproducing it fails too: a benchmark
    that silently stopped emitting a gated row is a regression, not a
    skip. Baseline rows without a recorded ``module`` predate that key and
    are exempt from the missing-row check."""
    base = {r.get("name"): r for r in baseline}
    fresh = {r.get("name") for r in records}
    failures = []
    for rec in records:
        ref = base.get(rec.get("name"))
        if ref is None:
            continue
        breaches = _field_breaches(rec, ref, tolerance)
        if breaches:
            failures.append(f"{rec['name']}: " + "; ".join(breaches)
                            + " (recorded baseline)")
    for ref in baseline:
        if (ref.get("name") not in fresh
                and ref.get("module") in ran_modules):
            failures.append(
                f"{ref['name']}: baseline row missing from this run "
                f"(module {ref['module']} ran but did not emit it)")
    return failures


def main() -> None:
    filters, check, tolerance = _parse_args(sys.argv[1:])
    records = []
    ran_modules, errored = set(), []
    print("name,us_per_call,derived")
    t_start = time.time()
    for mod_name in MODULES:
        if filters and not any(f in mod_name for f in filters):
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run()
        except Exception:
            print(f"{mod_name},0,ERROR")
            traceback.print_exc()
            errored.append(mod_name)
            continue
        ran_modules.add(mod_name)
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.0f},\"{r['derived']}\"")
            rec = {k: v for k, v in r.items() if k != "result"}
            rec["module"] = mod_name
            records.append(rec)
        sys.stdout.flush()

    os.makedirs("experiments", exist_ok=True)
    try:
        with open("experiments/bench_results.json") as f:
            prior = json.load(f)
    except (OSError, json.JSONDecodeError):
        prior = []

    if check:
        # gate mode: compare against the recorded baseline, leave it as is.
        # A missing/corrupt baseline (or one sharing no rows with this run)
        # must FAIL — a gate that silently compares zero rows is no gate —
        # and so must a benchmark module that errored out: its rows never
        # reached the comparison at all.
        names = {r.get("name") for r in prior}
        comparable = [r for r in records if r.get("name") in names]
        if not comparable:
            print("# REGRESSION experiments/bench_results.json has no rows "
                  "matching this run — baseline missing or corrupt")
            raise SystemExit(1)
        failures = [f"module {m} raised instead of producing rows"
                    for m in errored]
        failures += check_regressions(records, prior, tolerance,
                                      ran_modules=ran_modules)
        print(f"# total wall: {time.time() - t_start:.0f}s; "
              f"--check: {len(comparable)} rows vs recorded baseline "
              f"(tolerance {tolerance:g}x)")
        if failures:
            for msg in failures:
                print(f"# REGRESSION {msg}")
            raise SystemExit(1)
        print("# bench regression gate: PASS")
        return

    # A filtered run updates its rows in place instead of clobbering the
    # other modules' records, so the trajectory file stays complete.
    if filters:
        fresh = {r["name"] for r in records}
        records = [r for r in prior if r.get("name") not in fresh] + records
    with open("experiments/bench_results.json", "w") as f:
        json.dump(records, f, indent=2, default=str)
    print(f"# total wall: {time.time() - t_start:.0f}s; "
          f"records -> experiments/bench_results.json")


if __name__ == "__main__":
    main()
