"""Fig. 1 / Fig. 9 — average JCT vs cluster load (FIFO, single-GPU trace,
128 GPUs). Synergy-TUNE sustains higher load than GPU-proportional; at high
load the paper reports up to 3.4x (and OPT within ~10% of TUNE)."""
from __future__ import annotations

import time

from benchmarks.common import FAST, run_policies, speedup
from repro.core.trace import TraceConfig, generate


def run():
    rows = []
    loads = (6.0, 8.0, 10.0) if FAST else (4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0)
    n_jobs = 900 if FAST else 2500
    mon = (300, 400) if FAST else (600, 1000)
    for load in loads:
        jobs = generate(TraceConfig(n_jobs=n_jobs, split=(20, 70, 10),
                                    arrival="poisson", jobs_per_hour=load,
                                    multi_gpu=False, seed=42))
        t0 = time.perf_counter()
        sub = run_policies(jobs, 16, ["fifo"], ["proportional", "tune"],
                           steady_skip=mon[0], steady_count=mon[1])
        sp = speedup(sub, "fifo")
        prop = next(r for r in sub if r["allocator"] == "proportional")
        tune = next(r for r in sub if r["allocator"] == "tune")
        rows.append({
            "name": f"fig9_load/{load:.0f}jobs_hr",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "derived": (f"prop={prop['avg_jct_h']:.1f}h tune={tune['avg_jct_h']:.1f}h "
                        f"speedup={sp:.2f}x"),
            "speedup": sp,
        })
    return rows
