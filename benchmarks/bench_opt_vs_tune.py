"""§5.6 — Synergy-OPT vs Synergy-TUNE: per-round solve-time scaling with
cluster size, and TUNE's throughput within ~10% of the ILP optimum."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST
from repro.core import opt
from repro.core.allocators import get_allocator
from repro.core.cluster import Cluster
from repro.core.policies import get_policy
from repro.core.profiler import OptimisticProfiler
from repro.core.trace import TraceConfig, generate


def run():
    rows = []
    sizes = (4, 16) if FAST else (4, 16, 64)
    prof = OptimisticProfiler()
    for n_servers in sizes:
        gaps, t_opt, t_tune = [], [], []
        for seed in range(3):
            jobs = generate(TraceConfig(n_jobs=n_servers * 14,
                                        split=(30, 50, 20), arrival="static",
                                        seed=seed))
            for j in jobs:
                prof.profile_job(j)
            cluster = Cluster(n_servers)
            run_set, free = [], cluster.total_gpus
            for j in get_policy("fifo").order(jobs, 0):
                if j.gpu_demand <= free:
                    run_set.append(j)
                    free -= j.gpu_demand
            t0 = time.perf_counter()
            res = opt.solve_ideal(run_set, cluster, integer=True,
                                  time_limit=60.0)
            t_opt.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            get_allocator("tune").schedule(Cluster(n_servers), run_set)
            t_tune.append(time.perf_counter() - t0)
            tput = sum(j.current_rate for j in run_set)
            gaps.append(tput / max(res.throughput, 1e-9))
        rows.append({
            "name": f"opt_vs_tune/{n_servers * 8}gpus",
            "us_per_call": float(np.mean(t_opt)) * 1e6,
            "derived": (f"opt_solve={np.mean(t_opt) * 1000:.0f}ms "
                        f"tune_solve={np.mean(t_tune) * 1000:.1f}ms "
                        f"tune/opt_tput={np.mean(gaps) * 100:.0f}% "
                        f"speedup={np.mean(t_opt) / np.mean(t_tune):.0f}x"),
            "tune_over_opt": float(np.mean(gaps)),
        })
    return rows
