"""Table 5 — physical-cluster (live runtime) vs simulator fidelity.

Deploy column: the LiveRuntime actually trains reduced-config assigned-arch
models under the scheduler's leases (CPU-worker + MinIO-capacity knobs are
real). Simulate column: the SAME jobs — same live-measured sensitivity
matrices — replayed through the event simulator. The paper's claims checked:
TUNE beats proportional on both columns, and deploy/simulate diverge by only
a few percent (paper: <5%).
"""
from __future__ import annotations

import copy
import time

from repro.core.cluster import Cluster, ServerSpec
from repro.core.job import Job
from repro.core.runtime import LiveJobSpec, LiveRuntime
from repro.core.simulator import SimConfig, Simulator

SPECS = [
    # (arch, preprocess_cost_s, dataset_gb) — two data-hungry, two light
    ("phi-3-vision-4.2b", 0.012, 0.4),
    ("qwen2-0.5b", 0.0004, 0.1),
    ("whisper-large-v3", 0.008, 0.4),
    ("llama3.2-1b", 0.0004, 0.1),
]
SERVER = ServerSpec(gpus=2, cpus=6.0, mem=2.0)
ITERS = 10


def _make_runtime(allocator: str) -> LiveRuntime:
    rt = LiveRuntime(n_servers=1, spec=SERVER, policy="srtf",
                     allocator=allocator, round_seconds=1.5, probe_iters=1)
    for i, (arch, cost, ds) in enumerate(SPECS):
        rt.submit(LiveJobSpec(i, arch, total_iters=ITERS, batch_size=4,
                              preprocess_cost_s=cost, dataset_gb=ds,
                              seq_len=16))
    return rt


def _sim_speedup(profiled_jobs) -> float:
    """Replay the live-measured profiles through the event simulator."""
    out = {}
    for alloc in ("proportional", "tune"):
        jobs = []
        for j in profiled_jobs:
            nj = Job(job_id=j.job_id, model_name=j.model_name,
                     gpu_demand=j.gpu_demand, arrival_time=0.0,
                     duration=ITERS * 4 / max(j.prop_rate, 1e-9))
            nj.matrix = j.matrix
            nj.prop_rate = j.prop_rate
            nj.demand_cpu, nj.demand_mem = j.demand_cpu, j.demand_mem
            jobs.append(nj)
        sim = Simulator(Cluster(1, SERVER), jobs,
                        SimConfig(policy="srtf", allocator=alloc,
                                  round_seconds=1.5))
        out[alloc] = sim.run().avg_jct
    return out["proportional"] / out["tune"]


def run():
    rows = []
    t0 = time.perf_counter()
    rt_prop = _make_runtime("proportional")
    profiled = [copy.deepcopy(lj.sched_job) for lj in rt_prop.jobs.values()]
    live_prop = rt_prop.run(max_rounds=120)
    rt_tune = _make_runtime("tune")
    live_tune = rt_tune.run(max_rounds=120)
    live_speedup = live_prop["avg_jct"] / live_tune["avg_jct"]
    sim_speedup = _sim_speedup(profiled)
    div = abs(live_speedup - sim_speedup) / sim_speedup * 100
    rows.append({
        "name": "table5/deploy_vs_simulate",
        "us_per_call": (time.perf_counter() - t0) * 1e6,
        "derived": (f"deploy_speedup={live_speedup:.2f}x "
                    f"sim_speedup={sim_speedup:.2f}x divergence={div:.0f}% "
                    f"finished={live_tune['finished']}/{live_tune['total']}"),
        "live_speedup": live_speedup,
        "sim_speedup": sim_speedup,
    })
    return rows
