"""Fig. 5 — optimistic profiling accuracy & cost vs exhaustive profiling.

(a) memory validation: estimated throughput across memory allocations vs the
    ground-truth model (paper: within 3%);
(b) CPU validation: binary-search probes (~8) vs exhaustive (24), curve error;
(c) profiling-time reduction (paper: 10x for the matrix; 30x overall).

Plus the serve-side loop closure: a measured-vs-analytic calibrate row that
runs a small profiled engine, fits (t_tok, t_fixed) from its dispatch
records via ``obs.ProfileStore.rate_fit``, and compares the sensitivity
knees ``serve/tenant.py`` derives from the measured constants against the
analytic defaults (ISSUE 8 / ROADMAP item 1).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.cluster import ServerSpec
from repro.core.profiler import OptimisticProfiler, ProfilerConfig
from repro.core.sensitivity import MODEL_ZOO, full_matrix


def run():
    spec = ServerSpec()
    prof = OptimisticProfiler(spec)
    rows = []
    for name in ("resnet18", "gnmt", "m5", "alexnet", "shufflenetv2"):
        model = MODEL_ZOO[name]
        rng = np.random.default_rng(hash(name) % 2**32)

        def noisy(c, model=model, rng=rng):
            from repro.core.sensitivity import throughput
            true = throughput(model, 1, c, 520.0, min_mem_gb=prof.cfg.min_mem_gb)
            return true * float(rng.normal(1.0, 0.02))   # +-2% measurement noise

        t0 = time.perf_counter()
        est = prof.profile(model, gpus=1, measure_fn=noisy)
        wall = (time.perf_counter() - t0) * 1e6
        truth = full_matrix(model, 1, est.cpu_points, est.mem_points,
                            min_mem_gb=prof.cfg.min_mem_gb)
        nz = truth.W > 0
        rel_err = np.abs(est.W[nz] - truth.W[nz]) / truth.W[nz]
        exhaustive_probes = truth.W.size
        rows.append({
            "name": f"fig5_profiling/{name}",
            "us_per_call": wall,
            "derived": (f"max_err={rel_err.max() * 100:.2f}% "
                        f"probes={est.profile_probes}/{exhaustive_probes} "
                        f"cost_reduction={exhaustive_probes / est.profile_probes:.0f}x"),
            "max_rel_err": float(rel_err.max()),
            "probes": est.profile_probes,
        })
    rows.extend(_measured_calibrate_rows())
    return rows


def _measured_calibrate_rows():
    """Close the serve-side loop: measured vs analytic calibrate.

    Runs a tiny profiled engine (mixed widths and horizons so the store
    sees >=2 distinct dispatched-token sizes), fits (t_tok, t_fixed) from
    the dispatch records, then builds the same tenant class profile twice
    — analytic defaults vs the measured fit — and reports the horizon-K
    knee each one puts at the full unit budget plus the fitted t_tok
    delta. Un-gated (wall time and fitted constants vary run to run); the
    row documents that the measured path yields a usable, distinct fit.
    """
    from repro.configs import get_config
    from repro.obs import DispatchProfiler, ProfileStore
    from repro.serve import ServeEngine
    from repro.serve.scheduler import ServeRequest
    from repro.serve.tenant import profile_class

    arch = "qwen2-0.5b"
    cfg = get_config(arch, smoke=True)
    prof = DispatchProfiler(cfg)
    eng = ServeEngine(cfg, max_len=64, n_slots=4, cache="paged",
                      block_size=8, decode_horizon=8, profiler=prof)
    rng = np.random.default_rng(11)

    def reqs():
        # staggered arrivals + mixed budgets => decode widths 1..4, mixed K
        return [ServeRequest(
            rng.integers(1, cfg.vocab_size,
                         size=int(rng.integers(4, 10))).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 9)),
            arrival_time=i / 2.0)
            for i in range(8)]

    t0 = time.perf_counter()
    for _ in range(2):                  # second pass is compile-warm
        eng.run(reqs())
    wall = (time.perf_counter() - t0) * 1e6

    store = ProfileStore()
    store.add_run(prof, arch=arch, backend="paged")
    fit = store.rate_fit(arch, "paged")

    kw = dict(units_per_req=2, concurrency=8, total_units=16, max_k=8)
    pa = profile_class("t", **kw)                               # analytic
    knee_a = pa.matrix.best_second_axis(kw["total_units"])
    if fit is None:
        derived = (f"fit=none (need >=2 distinct dispatch sizes) "
                   f"analytic_knee=K{knee_a:.0f}")
        t_tok_m = float("nan")
    else:
        pm = profile_class("t", **kw, store=store, arch=arch,
                           backend="paged")                     # measured
        knee_m = pm.matrix.best_second_axis(kw["total_units"])
        t_tok_m = pm.t_tok
        derived = (f"src={pm.source} knee a=K{knee_a:.0f} m=K{knee_m:.0f} "
                   f"t_tok a={pa.t_tok * 1e3:.2f}ms "
                   f"m={pm.t_tok * 1e3:.3f}ms "
                   f"d={abs(pm.t_tok - pa.t_tok) * 1e3:.2f}ms "
                   f"t_fixed m={pm.t_fixed * 1e3:.2f}ms")
    return [{
        "name": "fig5_profiling/measured-calibrate",
        "us_per_call": wall,
        "derived": derived,
        "t_tok_measured": t_tok_m,
    }]
