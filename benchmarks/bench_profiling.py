"""Fig. 5 — optimistic profiling accuracy & cost vs exhaustive profiling.

(a) memory validation: estimated throughput across memory allocations vs the
    ground-truth model (paper: within 3%);
(b) CPU validation: binary-search probes (~8) vs exhaustive (24), curve error;
(c) profiling-time reduction (paper: 10x for the matrix; 30x overall).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.cluster import ServerSpec
from repro.core.profiler import OptimisticProfiler, ProfilerConfig
from repro.core.sensitivity import MODEL_ZOO, full_matrix


def run():
    spec = ServerSpec()
    prof = OptimisticProfiler(spec)
    rows = []
    for name in ("resnet18", "gnmt", "m5", "alexnet", "shufflenetv2"):
        model = MODEL_ZOO[name]
        rng = np.random.default_rng(hash(name) % 2**32)

        def noisy(c, model=model, rng=rng):
            from repro.core.sensitivity import throughput
            true = throughput(model, 1, c, 520.0, min_mem_gb=prof.cfg.min_mem_gb)
            return true * float(rng.normal(1.0, 0.02))   # +-2% measurement noise

        t0 = time.perf_counter()
        est = prof.profile(model, gpus=1, measure_fn=noisy)
        wall = (time.perf_counter() - t0) * 1e6
        truth = full_matrix(model, 1, est.cpu_points, est.mem_points,
                            min_mem_gb=prof.cfg.min_mem_gb)
        nz = truth.W > 0
        rel_err = np.abs(est.W[nz] - truth.W[nz]) / truth.W[nz]
        exhaustive_probes = truth.W.size
        rows.append({
            "name": f"fig5_profiling/{name}",
            "us_per_call": wall,
            "derived": (f"max_err={rel_err.max() * 100:.2f}% "
                        f"probes={est.profile_probes}/{exhaustive_probes} "
                        f"cost_reduction={exhaustive_probes / est.profile_probes:.0f}x"),
            "max_rel_err": float(rel_err.max()),
            "probes": est.profile_probes,
        })
    return rows
