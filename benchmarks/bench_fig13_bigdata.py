"""Fig. 13 — vs big-data schedulers: DRF and Tetris with static multi-dim
demands vs their Synergy(-TUNE) variants on splits W1=(20,70,10) and
W2=(50,0,50). Paper: tuning improves DRF by 7.2x and Tetris by 1.8x on W2."""
from __future__ import annotations

import copy
import time

from benchmarks.common import FAST
from repro.core.simulator import SimConfig, Simulator
from repro.core.cluster import Cluster
from repro.core.allocators import get_allocator
from repro.core.policies import get_policy
from repro.core.trace import TraceConfig, generate


def _sim(jobs, n_servers, policy_name, alloc_name):
    cluster = Cluster(n_servers)
    cfg = SimConfig(policy="fifo", allocator="tune",
                    steady_skip=150, steady_count=200)
    sim = Simulator(cluster, copy.deepcopy(jobs), cfg,
                    policy=get_policy(policy_name, cluster),
                    allocator=get_allocator(alloc_name))
    return sim.run()


def run():
    rows = []
    n_jobs = 450 if FAST else 1000
    for wname, split in (("W1", (20, 70, 10)), ("W2", (50, 0, 50))):
        jobs = generate(TraceConfig(n_jobs=n_jobs, split=split,
                                    arrival="poisson", jobs_per_hour=7.5,
                                    multi_gpu=True, seed=31))
        for base_policy, static_alloc in (("drf", "static"), ("fifo", "tetris")):
            t0 = time.perf_counter()
            static = _sim(jobs, 16, base_policy, static_alloc)
            tuned = _sim(jobs, 16, base_policy, "tune")
            label = "drf" if base_policy == "drf" else "tetris"
            sp = static.avg_jct / tuned.avg_jct
            rows.append({
                "name": f"fig13_bigdata/{label}_{wname}",
                "us_per_call": (time.perf_counter() - t0) * 1e6,
                "derived": (f"static={static.avg_jct / 3600:.1f}h "
                            f"synergy={tuned.avg_jct / 3600:.1f}h "
                            f"speedup={sp:.2f}x"),
                "speedup": sp,
            })
    return rows
