"""Root pytest config: make `python -m pytest -x -q` work with no env setup.

1. Put src/ on sys.path (mirrors PYTHONPATH=src; also configured in
   pyproject.toml for pytest>=7, kept here for direct `pytest` invocations
   from any CWD and for tooling that imports this file).
2. Force a multi-device host platform BEFORE jax first initializes, so the
   sharding tests exercise real 8-way meshes on CPU. Skipped when the flag
   is already present (e.g. the 512-device dry-run sweep env) or when jax
   was somehow imported first (the flag would be locked in).
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

if ("jax" not in sys.modules
        and "--xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()
