"""Unit tests for the repro.dist sharding subsystem.

Run on the forced multi-device host platform (conftest.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax init), so
every constraint is exercised against a real (4, 2) ("data", "model") mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs --xla_force_host_platform_device_count=8")


def host_mesh():
    return jax.make_mesh((4, 2), ("data", "model"))


# ---------------------------------------------------------------------------
# off-mesh no-op contract
# ---------------------------------------------------------------------------
def test_off_mesh_everything_is_noop():
    assert shd.current_rules() is None
    x = jnp.ones((4, 8, 16))
    assert shd.shard(x, "batch", None, "ffn") is x
    assert shd.shard_spec(x, P("data", None, "model")) is x
    assert shd.attention_scheme(4, 64, 8, 64) is None


def test_rules_pop_on_exit_and_nest():
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with shd.axis_rules(mesh, {"batch": "data"}) as outer:
        assert shd.current_rules() is outer
        with shd.axis_rules(mesh, {"batch": None}) as inner:
            assert shd.current_rules() is inner
        assert shd.current_rules() is outer
    assert shd.current_rules() is None


# ---------------------------------------------------------------------------
# rule-table lookup
# ---------------------------------------------------------------------------
@needs_mesh
def test_rule_table_lookup_and_axis_sizes():
    mesh = host_mesh()
    table = shd.production_rules_table(False)
    with shd.axis_rules(mesh, table) as rules:
        assert rules.mesh_axes("batch") == "data"
        assert rules.mesh_axes("ffn") == "model"
        assert rules.mesh_axes("nonexistent") is None
        assert rules.mesh_axes(None) is None
        assert rules.axis_size("data") == 4
        assert rules.axis_size("model") == 2
        assert rules.axis_size(("data", "model")) == 8
        assert rules.axis_size(None) == 1
    # the table is copied at install time
    with shd.axis_rules(mesh, table) as rules:
        table["ffn"] = None
        assert rules.mesh_axes("ffn") == "model"


def test_production_table_variants():
    t = shd.production_rules_table(True)
    assert t["batch"] == ("pod", "data")
    assert t["kv_seq"] is None
    t = shd.production_rules_table(False, seq_shard=True)
    assert t["batch"] == "data"
    assert t["kv_seq"] == "data"
    assert t["vocab"] == t["experts"] == t["heads"] == "model"


# ---------------------------------------------------------------------------
# constraint helpers
# ---------------------------------------------------------------------------
@needs_mesh
def test_shard_applies_named_constraint():
    mesh = host_mesh()
    with shd.axis_rules(mesh, shd.production_rules_table(False)):
        out = jax.jit(lambda x: shd.shard(x, "batch", None, "ffn"))(
            jnp.ones((8, 4, 16)))
        assert out.sharding.is_equivalent_to(
            NamedSharding(mesh, P("data", None, "model")), 3)


@needs_mesh
def test_shard_drops_non_divisible_and_unknown_axes():
    mesh = host_mesh()
    with shd.axis_rules(mesh, shd.production_rules_table(False)):
        # batch 6 % 4 != 0 -> batch axis dropped, ffn kept
        out = jax.jit(lambda x: shd.shard(x, "batch", None, "ffn"))(
            jnp.ones((6, 4, 16)))
        assert out.sharding.is_equivalent_to(
            NamedSharding(mesh, P(None, None, "model")), 3)
    # a multi-pod table on a pod-less mesh: "pod" silently dropped
    with shd.axis_rules(mesh, shd.production_rules_table(True)):
        out = jax.jit(lambda x: shd.shard(x, "batch", None, None))(
            jnp.ones((8, 4, 16)))
        assert out.sharding.is_equivalent_to(
            NamedSharding(mesh, P(None, None, None)), 3)


@needs_mesh
def test_shard_spec_dedups_mesh_axes():
    mesh = host_mesh()
    with shd.axis_rules(mesh, shd.production_rules_table(False)):
        # "model" requested twice: first dim wins, second replicates
        out = jax.jit(lambda x: shd.shard_spec(x, P("model", "model")))(
            jnp.ones((4, 8)))
        assert out.sharding.is_equivalent_to(
            NamedSharding(mesh, P("model", None)), 2)


# ---------------------------------------------------------------------------
# attention scheme selection
# ---------------------------------------------------------------------------
@needs_mesh
def test_attention_scheme_head_sharded():
    with shd.axis_rules(host_mesh(), shd.production_rules_table(False)):
        s = shd.attention_scheme(8, 64, 8, 64)      # heads divide 'model'(2)
        assert s["q"] == P("data", None, "model", None)
        assert s["kv"] == P("data", None, "model", None)
        assert s["logits"] == P("data", "model", None, None)


@needs_mesh
def test_attention_scheme_q_seq_sharded():
    with shd.axis_rules(host_mesh(), shd.production_rules_table(False)):
        s = shd.attention_scheme(8, 64, 3, 64)      # 3 heads don't divide
        assert s["q"] == P("data", "model", None, None)
        assert s["kv"] == P("data", None, None, None)
        assert s["logits"] == P("data", None, "model", None)


@needs_mesh
def test_attention_scheme_decode_kv_seq_sharded():
    with shd.axis_rules(host_mesh(), shd.production_rules_table(False)):
        s = shd.attention_scheme(8, 1, 3, 64)       # decode, awkward heads
        assert s["q"] == P("data", None, None, None)
        assert s["kv"] == P("data", "model", None, None)
        assert s["logits"] == P("data", None, None, "model")


@needs_mesh
def test_attention_scheme_batch_fallbacks():
    with shd.axis_rules(host_mesh(), shd.production_rules_table(False)):
        s = shd.attention_scheme(3, 1, 3, 63)       # nothing fits but...
        assert s["q"] == P(None, None, None, None)  # ...batch-only scheme
        sh = shd.attention_scheme(4, 1, 3, 63)
        assert sh["q"] == P("data", None, None, None)
    with shd.axis_rules(host_mesh(), {"batch": None}):
        assert shd.attention_scheme(8, 64, 8, 64) is None   # empty table


# ---------------------------------------------------------------------------
# param pspecs
# ---------------------------------------------------------------------------
@needs_mesh
def test_param_pspecs_nested_pytree():
    mesh = host_mesh()
    S = jax.ShapeDtypeStruct
    pshape = {
        "emb": {"tok_emb": S((512, 256), jnp.float32)},
        "layers": {
            "attn": {"wq": S((2, 256, 512), jnp.float32),
                     "wo": S((2, 512, 256), jnp.float32)},
            "mlp": {"w_gate": S((2, 256, 512), jnp.float32),
                    "w_down": S((2, 512, 256), jnp.float32)},
            "we_gate_up": S((2, 4, 256, 512), jnp.float32),
            "norm1": S((2, 256), jnp.float32),
        },
        "final_norm": S((256,), jnp.float32),
    }
    with shd.axis_rules(mesh, shd.production_rules_table(False)) as rules:
        spec = shd.param_pspecs(pshape, rules)
    assert spec["emb"]["tok_emb"] == P("model", None)
    assert spec["layers"]["attn"]["wq"] == P(None, None, "model")
    assert spec["layers"]["attn"]["wo"] == P(None, "model", None)
    assert spec["layers"]["mlp"]["w_gate"] == P(None, None, "model")
    assert spec["layers"]["mlp"]["w_down"] == P(None, "model", None)
    # experts and ffn both map to 'model': expert parallelism wins
    assert spec["layers"]["we_gate_up"] == P(None, "model", None, None)
    assert spec["layers"]["norm1"] == P(None, None)
    assert spec["final_norm"] == P(None)
    # structure preserved leaf-for-leaf
    assert (jax.tree_util.tree_structure(spec,
                is_leaf=lambda x: isinstance(x, P)).num_leaves
            == jax.tree_util.tree_structure(pshape).num_leaves)


@needs_mesh
def test_param_pspecs_real_model_and_named():
    from repro.configs import get_config
    from repro.models.api import params_specs
    mesh = host_mesh()
    cfg = get_config("llama3.2-1b", smoke=True)
    pshape = params_specs(cfg)
    with shd.axis_rules(mesh, shd.production_rules_table(False)) as rules:
        pspec = shd.param_pspecs(pshape, rules)
        psharding = shd.named(pspec, mesh)
    leaves = jax.tree_util.tree_leaves(
        psharding, is_leaf=lambda x: isinstance(x, NamedSharding))
    assert leaves and all(isinstance(l, NamedSharding) for l in leaves)
    # every spec is full-rank and valid for its leaf
    for (path, leaf) in jax.tree_util.tree_flatten_with_path(pshape)[0]:
        spec = psharding
        for k in path:
            spec = spec[k.key]
        assert len(spec.spec) == len(leaf.shape), path


# ---------------------------------------------------------------------------
# semantics: sharding must not change results
# ---------------------------------------------------------------------------
@needs_mesh
def test_on_mesh_forward_matches_off_mesh():
    from repro.configs import get_config
    from repro.models.api import build_model, make_batch
    cfg = get_config("llama3.2-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, 4, 64, jax.random.key(1))
    ref = jax.jit(model.forward)(params, batch)
    with shd.axis_rules(host_mesh(), shd.production_rules_table(False)):
        out = jax.jit(model.forward)(params, batch)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# dry-run flow (the acceptance smoke): named shardings on the host mesh
# ---------------------------------------------------------------------------
@needs_mesh
def test_dryrun_host_mesh_smoke():
    from repro.launch.dryrun import lower_combo
    rec, compiled = lower_combo("qwen2-0.5b", "decode_32k", False,
                                probe=False, extra_cfg={"smoke": True},
                                mesh_kind="host")
    assert rec["mesh"] == "host"
    assert rec["n_chips"] == jax.device_count()
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert compiled is not None
