"""Serve subsystem tests: cache-pool mechanics, scheduler policies,
continuous-vs-static exactness, per-row decode positions, MoE one-pass
prefill, and sharded (host-mesh) decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model
from repro.serve import (CachePool, ContinuousScheduler, ServeEngine,
                         ServeRequest, sharded_engine)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs --xla_force_host_platform_device_count=8")


def _model(arch="llama3.2-1b"):
    return build_model(get_config(arch, smoke=True))


def _requests(cfg, lengths, arrivals=None, max_new=6, seed=5):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0.0] * len(lengths)
    return [ServeRequest(rng.integers(1, cfg.vocab_size, size=s)
                         .astype(np.int32),
                         max_new_tokens=max_new, arrival_time=a)
            for s, a in zip(lengths, arrivals)]


# ---------------------------------------------------------------------------
# cache pool
# ---------------------------------------------------------------------------
def test_cache_pool_alloc_free_fifo_reuse():
    pool = CachePool(_model(), n_slots=4, max_len=16)
    assert [pool.alloc() for _ in range(4)] == [0, 1, 2, 3]
    assert pool.alloc() is None                    # full
    assert pool.utilization == 1.0
    pool.free(2)
    pool.free(0)
    # freed slots are recycled FIFO: 2 was freed first, then 0
    assert pool.alloc() == 2
    assert pool.alloc() == 0
    pool.free(1)
    with pytest.raises(ValueError):
        pool.free(1)                               # double-free guard
    pool.free(3)
    assert pool.n_free == 2


def test_cache_pool_free_unallocated_raises():
    pool = CachePool(_model(), n_slots=2, max_len=16)
    with pytest.raises(ValueError):
        pool.free(0)


def test_cache_pool_write_targets_one_slot():
    model = _model()
    pool = CachePool(model, n_slots=3, max_len=8)
    slot = pool.alloc()
    row = jax.tree_util.tree_map(lambda l: jnp.ones_like(l),
                                 model.init_cache(1, 8))
    pool.write(slot, row)
    for s in range(3):
        got = pool.read_slot(s)
        val = float(jax.tree_util.tree_leaves(got)[0].sum())
        if s == slot:
            assert val > 0
        else:
            assert val == 0.0


def test_cache_pool_batch_axis_inference_all_families():
    # zamba2's grouped state leaves have batch at axis 2; the pool must find
    # the batch axis per leaf, not assume a global one.
    for arch in ("llama3.2-1b", "mamba2-780m", "zamba2-7b", "olmoe-1b-7b"):
        model = _model(arch)
        pool = CachePool(model, n_slots=3, max_len=8)
        for (path, buf), ax in zip(
                jax.tree_util.tree_flatten_with_path(pool.buffers)[0],
                jax.tree_util.tree_leaves(pool.batch_axes)):
            assert buf.shape[ax] == 3, (arch, path, buf.shape, ax)


def test_cache_pool_write_replaces_whole_row():
    model = _model()
    pool = CachePool(model, n_slots=2, max_len=8)
    slot = pool.alloc()
    ones = jax.tree_util.tree_map(lambda l: jnp.ones_like(l),
                                  model.init_cache(1, 8))
    pool.write(slot, ones)
    pool.free(slot)
    slot2 = pool.alloc()
    while slot2 != slot:                          # cycle back to the dirty slot
        pool.free(slot2)
        slot2 = pool.alloc()
    pool.write(slot, model.init_cache(1, 8))      # fresh (zero) tenant
    val = sum(float(l.sum()) for l in
              jax.tree_util.tree_leaves(pool.read_slot(slot)))
    assert val == 0.0


def test_cache_pool_write_rejects_mismatched_max_len():
    """Regression: a row cache built for a different max_len must be
    rejected, not silently broadcast across the slot's positions."""
    model = _model()
    pool = CachePool(model, n_slots=2, max_len=16)
    slot = pool.alloc()
    with pytest.raises(ValueError, match="max_len"):
        pool.write(slot, model.init_cache(1, 8))
    # the degenerate broadcastable case (max_len 1) must also be rejected
    with pytest.raises(ValueError, match="max_len"):
        pool.write(slot, model.init_cache(1, 1))
    pool.write(slot, model.init_cache(1, 16))        # matching row is fine


def test_cache_pool_write_rejects_mismatched_dtype():
    model = _model()
    pool = CachePool(model, n_slots=2, max_len=8)
    slot = pool.alloc()
    row = jax.tree_util.tree_map(lambda l: l.astype(jnp.bfloat16),
                                 model.init_cache(1, 8))
    with pytest.raises(ValueError, match="dtype"):
        pool.write(slot, row)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
class _StubPool:
    max_len = 64

    def __init__(self, n):
        from collections import deque
        self._free = deque(range(n))

    def alloc(self):
        return self._free.popleft() if self._free else None

    def free(self, slot):
        self._free.append(slot)


def test_scheduler_sjf_admits_shortest_first():
    cfg = get_config("llama3.2-1b", smoke=True)
    reqs = _requests(cfg, [9, 2, 5])
    sched = ContinuousScheduler(_StubPool(1), policy="sjf")
    for i, r in enumerate(reqs):
        r.job_id = i
        sched.submit(r)
    admitted = sched.admit()
    assert len(admitted) == 1 and admitted[0] is reqs[1]    # shortest prompt


def test_scheduler_fcfs_respects_arrivals_and_slots():
    cfg = get_config("llama3.2-1b", smoke=True)
    reqs = _requests(cfg, [4, 4, 4], arrivals=[0.0, 0.0, 5.0])
    sched = ContinuousScheduler(_StubPool(2), policy="fcfs")
    for i, r in enumerate(reqs):
        r.job_id = i
        sched.submit(r)
    assert [r.job_id for r in sched.admit()] == [0, 1]
    assert sched.admit() == []                    # req 2 hasn't arrived
    sched.step = 5
    assert sched.admit() == []                    # arrived, but pool is full
    reqs[0].output = [1] * reqs[0].max_new_tokens
    sched.evict_finished()
    assert [r.job_id for r in sched.admit()] == [2]


def test_scheduler_rejects_oversized_request():
    cfg = get_config("llama3.2-1b", smoke=True)
    sched = ContinuousScheduler(_StubPool(1), policy="fcfs")
    with pytest.raises(ValueError):
        sched.submit(ServeRequest(np.zeros(60, np.int32), max_new_tokens=10))


# ---------------------------------------------------------------------------
# continuous == static, per request
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                  "olmoe-1b-7b"])
def test_continuous_matches_static_per_request(arch):
    """Mixed lengths, staggered arrivals, slot reuse — outputs must be
    token-for-token identical to one static batch of the same requests."""
    cfg = get_config(arch, smoke=True)
    lengths, arrivals = [5, 3, 8, 2, 6], [0.0, 0.0, 1.0, 3.0, 4.0]

    static, _ = ServeEngine(cfg, max_len=32).run(_requests(cfg, lengths))
    cont, stats = ServeEngine(cfg, max_len=32, n_slots=2, policy="fcfs").run(
        _requests(cfg, lengths, arrivals))

    for a, b in zip(static, cont):
        assert a.output == b.output
    assert stats.slot_utilization > 0.5
    assert all(r.finished_at is not None for r in cont)


def test_sjf_same_outputs_different_order():
    cfg = get_config("llama3.2-1b", smoke=True)
    lengths = [8, 2, 6, 3]
    static, _ = ServeEngine(cfg, max_len=32).run(_requests(cfg, lengths))
    sjf, _ = ServeEngine(cfg, max_len=32, n_slots=1, policy="sjf").run(
        _requests(cfg, lengths))
    for a, b in zip(static, sjf):
        assert a.output == b.output
    # with one slot, SJF must finish the shortest prompt first
    order = sorted(range(len(sjf)), key=lambda i: sjf[i].finished_at)
    assert order[0] == 1


def test_static_engine_single_request_matches_teacher_forcing():
    cfg = get_config("llama3.2-1b", smoke=True)
    eng = ServeEngine(cfg, max_len=32)
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    out = eng.generate([ServeRequest(prompt, max_new_tokens=4)])[0].output
    toks = list(prompt)
    for _ in range(4):
        logits = eng.model.forward(eng.params,
                                   {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert out == toks[len(prompt):]


# ---------------------------------------------------------------------------
# MoE one-pass prefill (satellite: return_cache hook)
# ---------------------------------------------------------------------------
def test_moe_forward_return_cache_shapes_and_logits():
    cfg = get_config("olmoe-1b-7b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    base = model.module.forward(cfg, params, toks)
    logits, (k, v) = model.module.forward(cfg, params, toks,
                                          return_cache=True)
    assert k.shape == (cfg.n_layers, 2, 8, cfg.n_kv_heads,
                       cfg.resolved_head_dim)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(base))


def test_moe_engine_uses_one_pass_prefill():
    """The engine must NOT fall back to the O(S)-step scan for MoE: its
    prefill output must equal the forward pass + the decode must continue
    exactly from it (teacher-forcing parity like the dense engine)."""
    cfg = get_config("olmoe-1b-7b", smoke=True)
    eng = ServeEngine(cfg, max_len=32)
    prompt = np.array([7, 3, 9, 2, 11, 5], np.int32)
    out = eng.generate([ServeRequest(prompt, max_new_tokens=4)])[0].output
    toks = list(prompt)
    for _ in range(4):
        logits = eng.model.forward(eng.params,
                                   {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert out == toks[len(prompt):]


# ---------------------------------------------------------------------------
# per-row decode positions
# ---------------------------------------------------------------------------
def test_vector_pos_matches_scalar_pos():
    cfg = get_config("llama3.2-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(4, 16)
    tok = jax.random.randint(jax.random.key(1), (4, 1), 0, cfg.vocab_size)
    ls, cs = jax.jit(model.decode_step)(params, cache, tok, jnp.int32(3))
    lv, cv = jax.jit(model.decode_step)(
        params, cache, tok, jnp.full((4,), 3, jnp.int32))
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lv), atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(cs),
                    jax.tree_util.tree_leaves(cv)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_vector_pos_rows_are_independent():
    """Row i of a staggered-pos batched decode == a batch-1 decode at that
    row's position — the property continuous batching rests on."""
    cfg = get_config("llama3.2-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    max_len = 16
    prompt = jax.random.randint(jax.random.key(2), (1, 6), 0, cfg.vocab_size)
    cache1 = model.init_cache(1, max_len)
    step = jax.jit(model.decode_step)
    for t in range(6):
        _, cache1 = step(params, cache1, prompt[:, t:t + 1], jnp.int32(t))

    # batch of 3 slots: slot 1 holds the real request at pos 6, others idle
    cache3 = model.init_cache(3, max_len)
    cache3 = jax.tree_util.tree_map(
        lambda b3, b1: b3.at[:, 1:2].set(b1), cache3, cache1)
    tok = jnp.array([[0], [9], [0]], jnp.int32)
    pos = jnp.array([0, 6, 0], jnp.int32)
    l3, _ = step(params, cache3, tok, pos)
    l1, _ = step(params, cache1, jnp.array([[9]], jnp.int32), jnp.int32(6))
    np.testing.assert_allclose(np.asarray(l3[1]), np.asarray(l1[0]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# device-resident multi-step decode horizon
# ---------------------------------------------------------------------------
def _staggered_requests(cfg, seed=5):
    """Mixed lengths, staggered arrivals AND budgets: mid-horizon finishes
    (budgets 1/2/4 end inside a K=3/8 horizon) plus admission churn (slot
    reuse through a 2-slot pool)."""
    lengths, arrivals = [5, 3, 8, 2, 6], [0.0, 0.0, 1.0, 3.0, 4.0]
    budgets = [2, 9, 4, 7, 1]
    reqs = _requests(cfg, lengths, arrivals, seed=seed)
    for r, b in zip(reqs, budgets):
        r.max_new_tokens = b
    return reqs


@pytest.mark.parametrize("k", [1, 3, 8])
def test_decode_horizon_token_identity(k):
    """A K-step device-resident horizon must be token-identical to the
    classic per-token loop under mid-horizon finishes and admission churn
    (K=1 IS the classic loop; larger K may only change dispatch counts)."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    ref = _staggered_requests(cfg)
    for r in ref:
        r.arrival_time = 0.0
    ref, _ = ServeEngine(cfg, params=params, max_len=32,
                         decode_horizon=1).run(ref)

    out, st = ServeEngine(cfg, params=params, max_len=32, n_slots=2,
                          decode_horizon=k).run(_staggered_requests(cfg))
    for a, b in zip(ref, out):
        assert a.output == b.output
    assert st.decode_horizon == k
    assert all(r.finished_at is not None for r in out)
    if k > 1:
        # the scheduler intervenes at horizon boundaries: fewer jitted
        # dispatches (and host syncs) than decode steps
        assert st.decode_dispatches < st.steps
        assert st.host_syncs < st.steps + st.prefill_dispatches + 1


def test_horizon_dispatch_drop_static_batch():
    """Uniform budgets in a static batch: K=8 must cover the whole decode
    run in ceil((max_new - 1) / 8) horizon dispatches."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    reqs = lambda: _requests(cfg, [5, 3, 6], max_new=17)
    one, s1 = ServeEngine(cfg, params=params, max_len=32,
                          decode_horizon=1).run(reqs())
    hor, s8 = ServeEngine(cfg, params=params, max_len=32,
                          decode_horizon=8).run(reqs())
    for a, b in zip(one, hor):
        assert a.output == b.output
    assert s1.decode_dispatches == 16          # 1 prefill + 16 decode tokens
    assert s8.decode_dispatches == 2           # ceil(16 / 8)
    assert s8.steps == s1.steps == 16


def test_eos_token_stops_requests_early():
    """A row emitting the EOS token freezes mid-horizon: its output is the
    greedy output truncated at the first EOS (inclusive), it reports
    ``done``, and other rows are unaffected."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    reqs = lambda: _requests(cfg, [5, 3, 6], max_new=8)
    base, _ = ServeEngine(cfg, params=params, max_len=32).run(reqs())
    eos = base[0].output[3]
    out, _ = ServeEngine(cfg, params=params, max_len=32, n_slots=2,
                         eos_token=eos).run(reqs())
    stopped = 0
    for b, o in zip(base, out):
        want = b.output
        if eos in want:
            want = want[:want.index(eos) + 1]
            stopped += 1
            assert o.finished_early
        assert o.output == want
        assert o.done
    assert stopped >= 1


# ---------------------------------------------------------------------------
# sharded (host-mesh) serving
# ---------------------------------------------------------------------------
@needs_mesh
def test_sharded_decode_matches_single_device():
    cfg = get_config("qwen2-0.5b", smoke=True)
    lengths, arrivals = [5, 3, 8, 2, 6, 4, 7, 3], [0.0] * 4 + [2.0] * 4

    single, _ = ServeEngine(cfg, max_len=32).run(_requests(cfg, lengths))
    eng = sharded_engine(cfg, n_slots=8, max_len=32)
    sharded, _ = eng.run(_requests(cfg, lengths, arrivals))

    for a, b in zip(single, sharded):
        assert a.output == b.output


@needs_mesh
def test_sharded_cache_shardings_not_replicated():
    """Acceptance: the decode step runs with non-replicated cache shardings
    from launch.dryrun.cache_pspecs (KV heads over 'model', slots over
    'data')."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    eng = sharded_engine(cfg, n_slots=8, max_len=32)
    shardings = jax.tree_util.tree_leaves(eng.sharding.cache_sharding)
    assert shardings and all(not s.is_fully_replicated for s in shardings)
    out, _ = eng.run(_requests(cfg, [4, 6], max_new=3))
    # the pool buffers really are laid out sharded after a run
    for leaf in jax.tree_util.tree_leaves(
            eng.sharding.cache_sharding):
        assert not leaf.is_fully_replicated
    assert all(len(r.output) == 3 for r in out)


@needs_mesh
def test_sharded_ssm_family_runs():
    cfg = get_config("mamba2-780m", smoke=True)
    eng = sharded_engine(cfg, n_slots=8, max_len=32)
    single, _ = ServeEngine(cfg, max_len=32).run(_requests(cfg, [5, 3, 7]))
    sharded, _ = eng.run(_requests(cfg, [5, 3, 7]))
    for a, b in zip(single, sharded):
        assert a.output == b.output


# ---------------------------------------------------------------------------
# contiguous live-slot compaction (gather-decode-scatter)
# ---------------------------------------------------------------------------
def test_contiguous_compaction_skips_dead_rows_exactly():
    """When completions stagger, the contiguous engine decodes only the
    live rows (bucketed) via gather-decode-scatter — outputs must stay
    identical to per-request static serving while rows are saved.
    ``decode_horizon=2`` keeps horizon boundaries inside the run: the
    bucket can only shrink at a boundary, so one long horizon would
    (correctly) decode full-width throughout."""
    cfg = get_config("llama3.2-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(9)
    budgets = [2, 8, 3, 6]
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (5, 4, 6, 3)]

    reqs = lambda: [ServeRequest(p.copy(), max_new_tokens=m)
                    for p, m in zip(prompts, budgets)]
    pooled, stats = ServeEngine(cfg, params=params, max_len=32,
                                n_slots=4, decode_horizon=2).run(reqs())
    for r in pooled:
        solo, _ = ServeEngine(cfg, params=params, max_len=32).run(
            [ServeRequest(r.prompt.copy(),
                          max_new_tokens=r.max_new_tokens)])
        assert solo[0].output == r.output
    assert stats.decode_rows_saved > 0.0


@needs_mesh
@pytest.mark.parametrize("arch,cache", [
    ("qwen2-0.5b", "contiguous"),
    ("qwen2-0.5b", "paged"),
    ("olmoe-1b-7b", "contiguous"),
    ("olmoe-1b-7b", "paged"),
])
def test_sharded_bucketed_decode_parity(arch, cache):
    """Width-bucketed sharded compaction (dense/moe x contiguous/paged):
    staggered arrivals and budgets shrink the live set mid-run, so the
    sharded engine decodes power-of-two buckets rounded to the mesh 'data'
    axis instead of full n_slots width — outputs must stay token-identical
    to a single-device static run, and rows must actually be saved."""
    cfg = get_config(arch, smoke=True)
    lengths, arrivals = [5, 3, 8, 2, 6], [0.0, 0.0, 1.0, 2.0, 2.0]
    budgets = [2, 9, 4, 7, 3]

    def reqs(with_arrivals):
        rs = _requests(cfg, lengths,
                       arrivals if with_arrivals else None)
        for r, b in zip(rs, budgets):
            r.max_new_tokens = b
        return rs

    single, _ = ServeEngine(cfg, max_len=32, decode_horizon=1).run(
        reqs(False))
    eng = sharded_engine(cfg, n_slots=8, max_len=32, cache=cache,
                         block_size=8)
    sharded, stats = eng.run(reqs(True))
    for a, b in zip(single, sharded):
        assert a.output == b.output
    # the sharded pool no longer decodes full-width: the tail of the run
    # has <= 4 live rows, which buckets to the 'data' axis width (4), so
    # rows are saved even on the mesh.
    assert stats.decode_rows_saved > 0.0
    assert stats.max_active <= 5


def test_contiguous_compaction_recurrent_family():
    """The gather-decode-scatter path must honor each leaf's batch axis —
    mamba2's state leaves carry it off axis 0 like the KV stacks do."""
    cfg = get_config("mamba2-780m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(10)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (4, 5, 3)]
    budgets = [2, 7, 4]
    reqs = lambda: [ServeRequest(p.copy(), max_new_tokens=m)
                    for p, m in zip(prompts, budgets)]
    pooled, stats = ServeEngine(cfg, params=params, max_len=32,
                                n_slots=4, decode_horizon=2).run(reqs())
    for r in pooled:
        solo, _ = ServeEngine(cfg, params=params, max_len=32).run(
            [ServeRequest(r.prompt.copy(),
                          max_new_tokens=r.max_new_tokens)])
        assert solo[0].output == r.output
    assert stats.decode_rows_saved > 0.0
