"""Fault injection + trace replay tests: spec grammar, injector
determinism, BlockManager shrink/expand/flush/audit conservation, engine
recovery paths (regenerate / retry / drop) under all six fault kinds with
token identity against the fault-free reference, drop-aware stats,
truncated-trace tolerance, and the Philly replay mapping."""
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model
from repro.obs import (Tracer, load_trace, read_trace, validate_events)
from repro.serve import (BlockManager, Fault, FaultInjector, FaultSchedule,
                         ServeEngine, ServeRequest, philly_requests,
                         run_replay)
from repro.serve.tenant import TenantAllocation, TenantShare


def _model(arch="llama3.2-1b", **over):
    return build_model(get_config(arch, smoke=True).replace(**over))


def _requests(cfg, lengths, arrivals=None, max_new=5, seed=5):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0.0] * len(lengths)
    return [ServeRequest(rng.integers(1, cfg.vocab_size, size=s)
                         .astype(np.int32),
                         max_new_tokens=max_new, arrival_time=a)
            for s, a in zip(lengths, arrivals)]


# ---------------------------------------------------------------------------
# spec grammar + schedule mechanics
# ---------------------------------------------------------------------------
def test_fault_spec_parse():
    f = Fault.from_spec("pool_shrink@12:blocks=6:restore_after=20")
    assert (f.kind, f.step, f.blocks, f.restore_after) == \
        ("pool_shrink", 12.0, 6, 20.0)
    f = Fault.from_spec(" slot_kill@8 ")
    assert (f.kind, f.step, f.slot) == ("slot_kill", 8.0, None)
    sched = FaultSchedule.from_spec(
        "slot_kill@8,arrival_burst@4:n=2:tenant=t1,defer_storm@2:duration=3",
        seed=11)
    assert [f.kind for f in sched.faults] == \
        ["slot_kill", "arrival_burst", "defer_storm"]
    assert sched.seed == 11 and sched.faults[1].n_requests == 2


def test_fault_spec_errors():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault.from_spec("gamma_ray@3")
    with pytest.raises(ValueError, match="needs kind@step"):
        Fault.from_spec("slot_kill")
    with pytest.raises(ValueError, match="bad fault spec field"):
        Fault.from_spec("slot_kill@3:bogus=1")
    with pytest.raises(ValueError, match="needs tenant"):
        Fault.from_spec("tenant_slowdown@3")


def test_schedule_json_roundtrip(tmp_path):
    sched = FaultSchedule.from_spec(
        "pool_shrink@12:blocks=6:restore_after=20,tenant_slowdown@4:"
        "tenant=t0:duration=5", seed=3)
    p = tmp_path / "faults.json"
    p.write_text(json.dumps(sched.to_json()))
    back = FaultSchedule.from_json(str(p))
    assert back.seed == 3 and back.faults == sched.faults


def test_injector_due_and_restore_insertion():
    inj = FaultInjector(FaultSchedule.from_spec(
        "slot_kill@8,prefix_flush@4,pool_shrink@8:blocks=2"))
    assert inj.next_fault_step(0) == 4
    assert [f.kind for f in inj.due(4)] == ["prefix_flush"]
    # same-step faults pop together, declaration order preserved
    assert [f.kind for f in inj.due(9)] == ["slot_kill", "pool_shrink"]
    assert inj.due(100) == [] and inj.next_fault_step(0) is None
    # defer_restore re-inserts the inverse in step order
    shrink = Fault("pool_shrink", step=8, blocks=4, restore_after=6)
    inj.defer_restore(shrink, applied_step=9.0, blocks=3)
    assert inj.next_fault_step(9) == 15.0
    (restore,) = inj.due(15)
    assert (restore.kind, restore.blocks) == ("pool_restore", 3)
    # reset re-arms the declared schedule (not the consumed state)
    inj.reset()
    assert inj.next_fault_step(0) == 4


def test_injector_holds_and_precedence():
    inj = FaultInjector(FaultSchedule())
    req = ServeRequest(np.zeros(4, np.int32), max_new_tokens=1, tenant="t1")
    assert not inj.has_holds(0) and inj.hold_cause(req, 0) is None
    inj.hold("t1", until=5.0)
    assert inj.hold_cause(req, 3) == "tenant_slowdown"
    assert inj.hold_cause(req, 5) is None          # window is exclusive
    inj.hold(None, until=8.0)                      # global storm outranks
    assert inj.hold_cause(req, 3) == "defer_storm"
    assert inj.release_step(3) == 5.0 and inj.release_step(6) == 8.0
    assert inj.has_holds(7) and not inj.has_holds(8)


def test_injector_seeded_choices_replay():
    sched = FaultSchedule.from_spec("arrival_burst@2:n=3", seed=9)
    a, b = FaultInjector(sched), FaultInjector(sched)
    for inj in (a, b):
        inj.bind(vocab_size=97, max_len=32, n_slots=4)
    f = sched.faults[0]
    picks_a = [a.pick_slot([0, 2, 3]) for _ in range(5)]
    picks_b = [b.pick_slot([0, 2, 3]) for _ in range(5)]
    assert picks_a == picks_b
    assert a.pick_slot([0, 2, 3], want=2) == 2     # live want wins
    assert a.pick_slot([]) is None
    burst_a = [r.prompt.tolist() for r in a.burst_requests(f)]
    a.reset()
    for _ in range(5):
        a.pick_slot([0, 2, 3])
    assert [r.prompt.tolist() for r in a.burst_requests(f)] == burst_a


# ---------------------------------------------------------------------------
# BlockManager fault surface: shrink / expand / flush / audit
# ---------------------------------------------------------------------------
def test_shrink_expand_arithmetic_and_deficit():
    pool = BlockManager(_model(), n_slots=4, max_len=32, block_size=8,
                        n_blocks=8, watermark=0.25)
    assert pool.watermark_blocks == 2
    slot = pool.alloc_for(ServeRequest(np.zeros(17, np.int32),
                                       max_new_tokens=4))     # 3 blocks held
    assert pool.shrink(7) == 7                     # wants 7, 5 idle: deficit 2
    assert pool.n_blocks == 1 and pool.free_blocks == 0
    assert pool.report()["revoke_deficit"] == 2
    assert pool.watermark_blocks == 1              # ceil(0.25 * 1)
    pool.audit()
    pool.free(slot)                                # deficit collected first
    assert pool.report()["revoke_deficit"] == 0
    assert pool.free_blocks == 1
    pool.audit()
    assert pool.expand(100) == 7                   # only what was revoked
    assert pool.n_blocks == 8 and pool.free_blocks == 8
    assert pool.audit()["capacity"] == 8
    # at least one block of capacity always survives a shrink
    assert pool.shrink(100) == 7 and pool.n_blocks == 1
    pool.audit()


def test_shrink_while_shared_and_flush_at_nonzero_refcount():
    pool = BlockManager(_model(), n_slots=4, max_len=32, block_size=4,
                        n_blocks=12, watermark=0.0, prefix_cache=True)
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, 50, size=8).astype(np.int32)
    a = pool.alloc_for(ServeRequest(np.concatenate([prefix, [3, 4, 5]])
                                    .astype(np.int32), max_new_tokens=2))
    for j in range(2):
        pool.commit_block(a, j)                    # prefix blocks hittable
    b = pool.alloc_for(ServeRequest(np.concatenate([prefix, [7, 8]])
                                    .astype(np.int32), max_new_tokens=2))
    assert pool.prefix_blocks_hit == 2             # b shares both full blocks
    pool.audit()
    # shrink while blocks are shared: idle first, deficit for the rest
    pool.shrink(9)
    pool.audit()
    # flush at nonzero refcount: entries retire, blocks stay with holders
    flushed = pool.flush_prefix()
    assert flushed == 2
    pool.audit()
    # a newcomer with the same prefix must NOT hit retired entries
    hits0 = pool.prefix_blocks_hit
    pool.free(a)
    pool.audit()                                   # a's frees feed the deficit
    pool.free(b)                                   # last holder: blocks leave
    pool.audit()
    c = pool.alloc_for(ServeRequest(np.concatenate([prefix, [9]])
                                    .astype(np.int32), max_new_tokens=2))
    assert c is not None and pool.prefix_blocks_hit == hits0
    pool.audit()


def test_flush_prefix_frees_evictable_immediately():
    pool = BlockManager(_model(), n_slots=2, max_len=32, block_size=4,
                        n_blocks=8, watermark=0.0, prefix_cache=True)
    prompt = np.arange(1, 10, dtype=np.int32)      # two full blocks + tail
    s = pool.alloc_for(ServeRequest(prompt, max_new_tokens=2))
    for j in range(2):
        pool.commit_block(s, j)
    pool.free(s)
    assert pool.evictable_blocks == 2
    free_before = len(pool._free_blocks)
    assert pool.flush_prefix() == 2
    assert pool.evictable_blocks == 0
    assert len(pool._free_blocks) == free_before + 2
    pool.audit()


def test_audit_catches_seeded_corruption():
    pool = BlockManager(_model(), n_slots=2, max_len=32, block_size=8,
                        n_blocks=6, watermark=0.0)
    slot = pool.alloc_for(ServeRequest(np.zeros(9, np.int32),
                                       max_new_tokens=2))
    pool.audit()
    blk = int(pool.tables[slot, 0])
    pool._free_blocks.append(blk)                  # block now free AND held
    with pytest.raises(RuntimeError, match="block audit failed"):
        pool.audit()
    pool._free_blocks.pop()
    pool.audit()
    pool._revoked.append(99)                       # capacity arithmetic break
    with pytest.raises(RuntimeError, match="capacity arithmetic"):
        pool.audit()


def test_audit_under_preemption_storm():
    cfg = get_config("llama3.2-1b", smoke=True)
    eng = ServeEngine(cfg, max_len=32, n_slots=3, cache="paged",
                      block_size=8, n_blocks=6, watermark=0.0,
                      decode_horizon=2)
    out, stats = eng.run(_requests(cfg, [9, 12, 10, 8], max_new=8))
    assert stats.preemptions > 0                   # undersized pool: storms
    eng.pool.audit()
    assert all(len(r.output) == r.max_new_tokens for r in out)


def test_rescaled_reserves_proportions():
    alloc = TenantAllocation(
        shares={"a": TenantShare("a", units=8, k_cap=4, lanes=2, headroom=4),
                "b": TenantShare("b", units=8, k_cap=4, lanes=2, headroom=2)},
        total_units=16, max_k=8)
    assert alloc.rescaled_reserves(16) == {"a": 4, "b": 2}
    half = alloc.rescaled_reserves(8)
    assert sum(half.values()) == 3 and half["a"] >= half["b"]
    assert alloc.rescaled_reserves(0) == {"a": 0, "b": 0}
    assert alloc.rescaled_reserves(32) == {"a": 4, "b": 2}  # capped at 1.0


# ---------------------------------------------------------------------------
# engine recovery paths + determinism + exactness
# ---------------------------------------------------------------------------
def _chaos_engine(cfg, spec, seed=0, **kw):
    inj = FaultInjector(FaultSchedule.from_spec(spec, seed=seed))
    kw.setdefault("cache", "paged")
    kw.setdefault("block_size", 8)
    kw.setdefault("decode_horizon", 4)
    return ServeEngine(cfg, max_len=32, n_slots=3, injector=inj, **kw)


def test_slot_kill_regenerates_token_identical():
    cfg = get_config("llama3.2-1b", smoke=True)
    reqs = _requests(cfg, [9, 12, 10], max_new=6)
    ref, _ = ServeEngine(cfg, max_len=32, decode_horizon=1).run(
        _requests(cfg, [9, 12, 10], max_new=6))
    eng = _chaos_engine(cfg, "slot_kill@2,slot_kill@4")
    out, stats = eng.run(reqs)
    assert stats.faults_injected == 2
    assert stats.preemptions >= 1 and stats.recoveries >= 1
    assert stats.dropped == 0
    for r, rr in zip(sorted(out, key=lambda r: r.job_id),
                     sorted(ref, key=lambda r: r.job_id)):
        assert r.output == rr.output


def test_all_six_kinds_survive_and_verify():
    cfg = get_config("llama3.2-1b", smoke=True)
    spec = ("defer_storm@1:duration=2,tenant_slowdown@2:tenant=default:"
            "duration=2,slot_kill@3,arrival_burst@4:n=2:prompt_len=8:"
            "max_new=3,prefix_flush@5,pool_shrink@6:blocks=3:restore_after=4")
    eng = _chaos_engine(cfg, spec, seed=1, prefix_cache=True,
                        tracer=Tracer())
    reqs = _requests(cfg, [9, 12, 10, 8], arrivals=[0, 0, 2, 5], max_new=5)
    res = run_replay(eng, reqs, verify=True, ref_cfg=cfg, ref_max_len=32)
    # all six kinds applied (+ the auto-scheduled pool_restore inverse)
    assert {k for k, _ in res.faults} == {
        "defer_storm", "tenant_slowdown", "slot_kill", "arrival_burst",
        "prefix_flush", "pool_shrink", "pool_restore"}
    assert res.stats.faults_injected == len(res.faults) == 7
    assert len(res.requests) == 6                  # 4 + 2 burst arrivals
    assert res.verified and not res.mismatched
    eng.pool.audit()
    assert not validate_events(list(eng.tracer.events))


def test_chaos_replay_is_deterministic():
    cfg = get_config("llama3.2-1b", smoke=True)
    spec = "slot_kill@2,arrival_burst@3:n=2:prompt_len=8:max_new=3," \
           "pool_shrink@4:blocks=2:restore_after=3"

    def once():
        eng = _chaos_engine(cfg, spec, seed=5, tracer=Tracer())
        out, stats = eng.run(_requests(cfg, [9, 12, 10], max_new=5))
        evs = [{k: v for k, v in e.items()
                if k not in ("t", "wall_t", "dur_s")}
               for e in eng.tracer.events
               if e["ev"] in ("fault_inject", "recover", "admit", "preempt",
                              "evict", "defer")]
        return ([r.output for r in out], list(eng.injector.injected), evs)

    assert once() == once()


def test_pool_shrink_drops_score_separately():
    cfg = get_config("llama3.2-1b", smoke=True)
    # shrink to (almost) nothing with no restore: late arrivals can never
    # admit again and must drop after bounded retries, not wedge the run.
    eng = _chaos_engine(cfg, "pool_shrink@2:blocks=64", n_blocks=12,
                        max_admit_retries=2)
    reqs = _requests(cfg, [9, 12, 10, 11], arrivals=[0, 0, 6, 6], max_new=4)
    out, stats = eng.run(reqs)
    assert stats.dropped >= 1
    dropped = [r for r in out if r.dropped]
    assert all(r.drop_cause == "pool_shrink" and r.output == []
               for r in dropped)
    scored = [r for r in out if not r.dropped]
    assert all(len(r.output) == r.max_new_tokens for r in scored)
    # drops are NOT unfinished, and attainment is over the scored set only
    assert stats.unfinished == 0
    assert stats.slo_attainment == 1.0
    eng.pool.audit()


def test_contiguous_cache_survives_chaos():
    cfg = get_config("llama3.2-1b", smoke=True)
    inj = FaultInjector(FaultSchedule.from_spec(
        "slot_kill@2,pool_shrink@3:blocks=4,prefix_flush@4"))
    eng = ServeEngine(cfg, max_len=32, n_slots=2, cache="contiguous",
                      decode_horizon=2, injector=inj)
    ref, _ = ServeEngine(cfg, max_len=32, decode_horizon=1).run(
        _requests(cfg, [9, 12, 10], max_new=5))
    out, stats = eng.run(_requests(cfg, [9, 12, 10], max_new=5))
    assert stats.faults_injected == 3              # shrink/flush no-op, logged
    for r, rr in zip(sorted(out, key=lambda r: r.job_id),
                     sorted(ref, key=lambda r: r.job_id)):
        assert r.output == rr.output


# ---------------------------------------------------------------------------
# truncated traces + fault report
# ---------------------------------------------------------------------------
def test_read_trace_tolerates_truncated_tail(tmp_path):
    p = tmp_path / "t.jsonl"
    rows = [{"ev": "run_start", "step": 0}, {"ev": "admit", "step": 1}]
    p.write_text("\n".join(json.dumps(r) for r in rows)
                 + "\n" + '{"ev": "evi')
    events, truncated = read_trace(str(p))
    assert truncated and events == rows
    assert load_trace(str(p)) == rows              # back-compat wrapper
    # a clean file reports no truncation
    p.write_text("\n".join(json.dumps(r) for r in rows) + "\n")
    events, truncated = read_trace(str(p))
    assert not truncated and events == rows
    # corruption in the MIDDLE is a real error, not writer tail-loss
    p.write_text('{"ev": "bro\n' + json.dumps(rows[0]) + "\n")
    with pytest.raises(json.JSONDecodeError):
        read_trace(str(p))


def test_trace_report_fault_table_and_validate(tmp_path):
    from repro.launch.trace_report import build_report, main
    cfg = get_config("llama3.2-1b", smoke=True)
    eng = _chaos_engine(cfg, "slot_kill@2,pool_shrink@3:blocks=64",
                        n_blocks=12, max_admit_retries=1, tracer=Tracer())
    eng.run(_requests(cfg, [9, 12, 10], arrivals=[0, 0, 6], max_new=4))
    p = tmp_path / "chaos.jsonl"
    eng.tracer.dump_jsonl(str(p))
    report = build_report(load_trace(str(p)))
    assert report["faults"]["injected"] == {"pool_shrink": 1, "slot_kill": 1}
    actions = {(r["kind"], r["action"]): r["n"]
               for r in report["faults"]["recoveries"]}
    assert actions[("slot_kill", "regenerate")] == 1
    assert ("pool_shrink", "drop") in actions
    assert report["faults"]["drops"] >= 1
    # --validate passes the chaos trace and tolerates a truncated tail
    assert main([str(p), "--validate", "--json"]) == 0
    with open(p, "a") as f:
        f.write('{"ev": "adm')
    assert main([str(p), "--validate", "--json"]) == 0


# ---------------------------------------------------------------------------
# Philly replay mapping
# ---------------------------------------------------------------------------
def test_philly_requests_deterministic_and_shaped():
    a = philly_requests(257, 12, load=2.0, seed=3, prompt_len=12,
                        max_new=8, max_len=64)
    b = philly_requests(257, 12, load=2.0, seed=3, prompt_len=12,
                        max_new=8, max_len=64)
    assert len(a) == 12
    assert [r.prompt.tolist() for r in a] == [r.prompt.tolist() for r in b]
    assert [r.arrival_time for r in a] == [r.arrival_time for r in b]
    for r in a:
        assert 1 <= len(r.prompt) <= 12
        assert 1 <= r.max_new_tokens <= 8
        assert len(r.prompt) + r.max_new_tokens <= 64
    assert a != philly_requests(257, 12, load=2.0, seed=4, prompt_len=12,
                                max_new=8, max_len=64)
    with pytest.raises(ValueError, match="load"):
        philly_requests(257, 4, load=0.0)


def test_run_replay_verify_requires_ref_cfg():
    cfg = get_config("llama3.2-1b", smoke=True)
    eng = ServeEngine(cfg, max_len=32, n_slots=2, decode_horizon=2)
    with pytest.raises(ValueError, match="ref_cfg"):
        run_replay(eng, _requests(cfg, [6, 8], max_new=2), verify=True)
