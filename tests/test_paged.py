"""Paged KV subsystem tests: BlockManager mechanics, watermark admission,
block-table reuse without leaks, chunked prefill == one-pass prefill,
paged-vs-contiguous exactness across attention families, preemption,
admission density vs the contiguous pool, sampling lanes, and sharded
(host-mesh) paged decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model
from repro.serve import BlockManager, ServeEngine, ServeRequest, sharded_engine

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs --xla_force_host_platform_device_count=8")

PAGED_ARCHS = ("llama3.2-1b", "olmoe-1b-7b", "phi-3-vision-4.2b")


def _model(arch="llama3.2-1b", **over):
    return build_model(get_config(arch, smoke=True).replace(**over))


def _requests(cfg, lengths, arrivals=None, max_new=5, seed=5):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0.0] * len(lengths)
    return [ServeRequest(rng.integers(1, cfg.vocab_size, size=s)
                         .astype(np.int32),
                         max_new_tokens=max_new, arrival_time=a)
            for s, a in zip(lengths, arrivals)]


# ---------------------------------------------------------------------------
# BlockManager mechanics
# ---------------------------------------------------------------------------
def test_block_manager_length_proportional_alloc():
    pool = BlockManager(_model(), n_slots=4, max_len=32, block_size=8,
                        n_blocks=8, watermark=0.0)
    assert pool.blocks_for(40) == 5
    r = ServeRequest(np.zeros(17, np.int32), max_new_tokens=4)  # 3 blocks
    slot = pool.alloc_for(r)
    assert slot == 0
    assert (pool.tables[0] >= 0).sum() == 3          # ceil(17/8), not max_len
    assert pool.free_blocks == 5
    # growth appends one block when a boundary is crossed
    assert pool.ensure(slot, 24)
    assert (pool.tables[0] >= 0).sum() == 3          # 24 = 3*8 exactly
    assert pool.ensure(slot, 25)
    assert (pool.tables[0] >= 0).sum() == 4
    pool.free(slot)
    assert pool.free_blocks == 8
    assert (pool.tables[0] == -1).all()              # stale table cleared


def test_block_manager_fifo_reuse_and_guards():
    pool = BlockManager(_model(), n_slots=2, max_len=16, block_size=8,
                        n_blocks=3, watermark=0.0)
    a = pool.alloc_for(ServeRequest(np.zeros(8, np.int32), max_new_tokens=1))
    b = pool.alloc_for(ServeRequest(np.zeros(16, np.int32), max_new_tokens=0))
    assert (a, b) == (0, 1)
    first_blocks = list(pool.tables[0][pool.tables[0] >= 0])
    pool.free(a)
    with pytest.raises(ValueError):
        pool.free(a)                                 # double-free guard
    pool.free(b)
    # freed blocks recycle FIFO: slot 0's block returns before slot 1's
    c = pool.alloc_for(ServeRequest(np.zeros(8, np.int32), max_new_tokens=1))
    assert list(pool.tables[c][pool.tables[c] >= 0]) == first_blocks
    with pytest.raises(ValueError):
        pool.ensure(5, 1)                            # unallocated slot


def test_block_manager_watermark_admission():
    pool = BlockManager(_model(), n_slots=4, max_len=32, block_size=8,
                        n_blocks=6, watermark=0.34)    # reserve = 3 blocks
    assert pool.watermark_blocks == 3
    assert pool.can_admit(16)                          # 2 blocks, 4 - 2 >= 3?
    assert not pool.can_admit(32)                      # 4 blocks violates
    r = ServeRequest(np.zeros(16, np.int32), max_new_tokens=4)
    slot = pool.alloc_for(r)
    assert slot is not None and pool.free_blocks == 4
    # decode growth may eat the reserve...
    assert pool.ensure(slot, 40 - 8)
    assert pool.free_blocks == 2
    # ...but admission never does
    assert pool.alloc_for(r) is None


def test_block_manager_validate_request():
    pool = BlockManager(_model(), n_slots=2, max_len=16, block_size=4,
                        n_blocks=4, watermark=0.0)
    with pytest.raises(ValueError):                    # table span
        pool.validate_request(ServeRequest(np.zeros(14, np.int32),
                                           max_new_tokens=4))
    with pytest.raises(ValueError):                    # total blocks
        BlockManager(_model(), n_slots=2, max_len=32, block_size=4,
                     n_blocks=4, watermark=0.0).validate_request(
            ServeRequest(np.zeros(20, np.int32), max_new_tokens=4))
    with pytest.raises(ValueError):                    # watermark-infeasible
        BlockManager(_model(), n_slots=2, max_len=16, block_size=4,
                     n_blocks=4, watermark=0.5).validate_request(
            ServeRequest(np.zeros(12, np.int32), max_new_tokens=2))


def test_block_manager_report_occupancy_and_fragmentation():
    pool = BlockManager(_model(), n_slots=2, max_len=16, block_size=8,
                        n_blocks=4, watermark=0.0)
    pool.alloc_for(ServeRequest(np.zeros(9, np.int32), max_new_tokens=1))
    rep = pool.report()
    assert rep["used_blocks"] == 2 and rep["occupancy"] == 0.5
    assert rep["used_tokens"] == 9 and rep["allocated_tokens"] == 16
    assert rep["internal_fragmentation"] == pytest.approx(7 / 16)


def test_block_manager_rejects_recurrent_family():
    with pytest.raises(ValueError):
        BlockManager(_model("mamba2-780m"), n_slots=2, max_len=16)
    with pytest.raises(ValueError):
        ServeEngine(get_config("mamba2-780m", smoke=True), cache="paged")


# ---------------------------------------------------------------------------
# chunked prefill == one-pass prefill
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_chunked_prefill_matches_one_pass(arch):
    cfg = get_config(arch, smoke=True).replace(decode_attention="paged")
    ccfg = cfg.replace(decode_attention="contiguous")
    model, cmodel = build_model(cfg), build_model(ccfg)
    params = model.init(jax.random.key(0))
    s, bs = 11, 4
    prompt = jax.random.randint(jax.random.key(1), (1, s), 0, cfg.vocab_size)

    full_logits, (k_full, v_full) = cmodel.module.forward(
        ccfg, params, prompt, return_cache=True)

    cache = model.init_paged_cache(8, bs)
    tables = np.full((1, 6), -1, np.int32)
    nblk = -(-s // bs)
    tables[0, :nblk] = np.arange(nblk)
    tables = jnp.asarray(tables)
    state = model.paged_prefill_state(1)
    for i0 in range(0, s, bs):
        logits, cache, state = model.paged_prefill_chunk(
            params, cache, prompt[:, i0:i0 + bs], jnp.int32(i0), tables,
            state, s)
    np.testing.assert_allclose(np.asarray(logits[0, -1]),
                               np.asarray(full_logits[0, -1]),
                               atol=2e-4, rtol=2e-4)
    # the paged cache holds the same K/V at every valid logical position
    paged_k = np.asarray(cache["k"])[:, tables[0, :nblk]]       # [L,NB,BS,..]
    paged_k = paged_k.reshape(cfg.n_layers, 1, nblk * bs, *paged_k.shape[3:])
    np.testing.assert_allclose(paged_k[:, :, :s],
                               np.asarray(k_full), atol=1e-5, rtol=1e-5)


def test_paged_prefill_ignores_stale_blocks():
    """A dirty block pool (a previous tenant's K/V everywhere) must produce
    the same outputs as a fresh pool: the gather mask can never reach beyond
    a request's own written positions."""
    cfg = get_config("llama3.2-1b", smoke=True).replace(
        decode_attention="paged")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    s, bs = 7, 4
    prompt = jax.random.randint(jax.random.key(1), (1, s), 0, cfg.vocab_size)
    tables = jnp.asarray(np.array([[3, 1, -1]], np.int32))

    def run(cache):
        state = model.paged_prefill_state(1)
        for i0 in range(0, s, bs):
            logits, cache, state = model.paged_prefill_chunk(
                params, cache, prompt[:, i0:i0 + bs], jnp.int32(i0), tables,
                state, s)
        tok = jnp.argmax(logits[0, -1])[None, None].astype(jnp.int32)
        dl, _ = model.paged_decode_step(params, cache, tok,
                                        jnp.full((1,), s, jnp.int32), tables)
        return logits, dl

    clean = model.init_paged_cache(6, bs)
    dirty = jax.tree_util.tree_map(lambda l: jnp.ones_like(l) * 37.0, clean)
    for a, b in zip(run(clean), run(dirty)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# paged continuous == contiguous static, per request
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_matches_contiguous_static_per_request(arch):
    """Mixed lengths, staggered arrivals, block reuse — paged continuous
    outputs must be token-for-token identical to one contiguous static batch
    (the acceptance invariant, also checked by launch.serve --verify)."""
    cfg = get_config(arch, smoke=True)
    lengths, arrivals = [5, 3, 8, 2, 6], [0.0, 0.0, 1.0, 3.0, 4.0]

    static, _ = ServeEngine(cfg, max_len=32).run(_requests(cfg, lengths))
    paged, stats = ServeEngine(cfg, max_len=32, n_slots=3, cache="paged",
                               block_size=4).run(
        _requests(cfg, lengths, arrivals))

    for a, b in zip(static, paged):
        assert a.output == b.output
    assert all(r.finished_at is not None for r in paged)
    # idle-slot compaction: the paged engine decoded fewer rows than
    # steps * n_slots would have
    assert stats.decode_rows_saved > 0.0
    assert stats.block_report["block_size"] == 4


def test_paged_block_reuse_never_leaks_prior_kv():
    """A freed request's blocks are re-issued to a new tenant (the pool is
    sized so reuse is forced) and the tenant's outputs equal a run on a
    fresh pool — the block-granular mirror of the slot-recycle test."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    lengths = [6, 7, 5]
    # 4 blocks of 4 = 16 positions: each request needs 2-3 blocks, so with
    # one slot every later request reuses the earlier tenants' blocks.
    shared, _ = ServeEngine(cfg, params=params, max_len=16, n_slots=1,
                            cache="paged", block_size=4, n_blocks=4,
                            watermark=0.0).run(_requests(cfg, lengths))
    for r in shared:
        fresh, _ = ServeEngine(cfg, params=params, max_len=16,
                               cache="paged", block_size=4).run(
            [ServeRequest(r.prompt.copy(),
                          max_new_tokens=r.max_new_tokens)])
        assert fresh[0].output == r.output


def test_paged_preemption_regenerates_identically():
    """Under block pressure the engine preempts the most recently admitted
    request; after re-admission its tokens regenerate identically."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    reqs = _requests(cfg, [8, 8], max_new=8)
    static, _ = ServeEngine(cfg, params=params, max_len=32).run(
        _requests(cfg, [8, 8], max_new=8))
    # each request grows to 16 tokens = 4 blocks; 6 blocks cannot hold both
    paged, stats = ServeEngine(cfg, params=params, max_len=32, n_slots=2,
                               cache="paged", block_size=4, n_blocks=6,
                               watermark=0.0).run(reqs)
    assert stats.preemptions >= 1
    for a, b in zip(static, paged):
        assert a.output == b.output


def test_paged_admits_where_contiguous_refuses():
    """Equal token budgets: the contiguous pool rejects a prompt longer than
    its per-slot max_len outright, and serves fewer requests concurrently at
    mixed lengths — the admission-density acceptance criterion."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    budget = 128                                     # cache positions

    # (a) hard refusal: one 40-token prompt. Contiguous spends the budget as
    # 4 slots x 32 positions -> submit raises; paged spans 64 positions of
    # table while spending the same 128 pooled positions -> serves it.
    long_req = [ServeRequest(np.arange(1, 41, dtype=np.int32),
                             max_new_tokens=4)]
    with pytest.raises(ValueError):
        ServeEngine(cfg, params=params, max_len=32, n_slots=4).run(
            [ServeRequest(long_req[0].prompt.copy(), max_new_tokens=4)])
    out, _ = ServeEngine(cfg, params=params, max_len=64, n_slots=4,
                         cache="paged", block_size=8, n_blocks=16,
                         watermark=0.0).run(long_req)
    assert len(out[0].output) == 4

    # (b) density: 8 mixed-length requests. Contiguous: 128/32 = 4 slots.
    # Paged: same 128 positions as 16 blocks of 8 serve all 8 at once.
    lengths = [4, 6, 5, 7, 4, 6, 5, 7]
    cont, cs = ServeEngine(cfg, params=params, max_len=32, n_slots=4).run(
        _requests(cfg, lengths, max_new=4))
    paged, ps = ServeEngine(cfg, params=params, max_len=32, n_slots=8,
                            cache="paged", block_size=8, n_blocks=16,
                            watermark=0.0).run(_requests(cfg, lengths,
                                                         max_new=4))
    assert cs.max_active == 4
    assert ps.max_active == 8
    assert ps.steps < cs.steps
    for a, b in zip(cont, paged):
        assert a.output == b.output


# ---------------------------------------------------------------------------
# sampling lanes (per-slot RNG)
# ---------------------------------------------------------------------------
def test_sampling_lanes_deterministic_and_greedy_default():
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    lengths = [5, 3, 6]

    greedy, _ = ServeEngine(cfg, params=params, max_len=32).run(
        _requests(cfg, lengths))
    # top-k=1 sampling degenerates to greedy whatever the temperature
    top1, _ = ServeEngine(cfg, params=params, max_len=32, temperature=0.9,
                          top_k=1).run(_requests(cfg, lengths))
    for a, b in zip(greedy, top1):
        assert a.output == b.output

    eng = ServeEngine(cfg, params=params, max_len=32, temperature=8.0,
                      sample_seed=7)
    s1, _ = eng.run(_requests(cfg, lengths))
    s2, _ = eng.run(_requests(cfg, lengths))
    for a, b in zip(s1, s2):                 # same lanes -> same samples
        assert a.output == b.output
    assert any(a.output != g.output for a, g in zip(s1, greedy))


def test_sampling_lanes_work_with_paged_cache():
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    eng = ServeEngine(cfg, params=params, max_len=32, n_slots=2,
                      cache="paged", block_size=4, temperature=0.8,
                      sample_seed=3)
    out, _ = eng.run(_requests(cfg, [5, 4, 6], max_new=4))
    assert all(len(r.output) == 4 for r in out)


# ---------------------------------------------------------------------------
# Pallas kernel path inside the model
# ---------------------------------------------------------------------------
def test_paged_decode_step_pallas_matches_gather():
    cfg = get_config("llama3.2-1b", smoke=True).replace(
        decode_attention="paged")
    model = build_model(cfg)
    pmodel = build_model(cfg.replace(use_pallas=True))
    params = model.init(jax.random.key(0))
    s, bs = 6, 4
    prompt = jax.random.randint(jax.random.key(1), (1, s), 0, cfg.vocab_size)
    cache = model.init_paged_cache(6, bs)
    tables = jnp.asarray(np.array([[0, 1, -1, -1]], np.int32))
    state = model.paged_prefill_state(1)
    for i0 in range(0, s, bs):
        logits, cache, state = model.paged_prefill_chunk(
            params, cache, prompt[:, i0:i0 + bs], jnp.int32(i0), tables,
            state, s)
    tok = jnp.argmax(logits[0, -1])[None, None].astype(jnp.int32)
    pos = jnp.full((1,), s, jnp.int32)
    ref_logits, _ = model.paged_decode_step(params, cache, tok, pos, tables)
    pal_logits, _ = pmodel.paged_decode_step(params, cache, tok, pos, tables)
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(pal_logits),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# sharded (host-mesh) paged serving
# ---------------------------------------------------------------------------
@needs_mesh
def test_sharded_paged_matches_single_device_contiguous():
    cfg = get_config("qwen2-0.5b", smoke=True)
    lengths, arrivals = [5, 3, 8, 2, 6, 4], [0.0] * 3 + [2.0] * 3

    single, _ = ServeEngine(cfg, max_len=32).run(_requests(cfg, lengths))
    eng = sharded_engine(cfg, n_slots=4, max_len=32, cache="paged",
                         block_size=8)
    sharded, stats = eng.run(_requests(cfg, lengths, arrivals))

    for a, b in zip(single, sharded):
        assert a.output == b.output
    assert stats.block_report is not None
    # the paged pool's K/V leaves really are laid out sharded
    shardings = jax.tree_util.tree_leaves(eng.sharding.cache_sharding)
    assert shardings and all(not s.is_fully_replicated for s in shardings)


# ---------------------------------------------------------------------------
# prefix cache: refcounting, copy-on-write tail, token identity
# ---------------------------------------------------------------------------
def _prefix_pool(**over):
    kw = dict(n_slots=4, max_len=32, block_size=4, n_blocks=16,
              watermark=0.0, prefix_cache=True)
    kw.update(over)
    return BlockManager(_model(), **kw)


def _commit_full_blocks(pool, slot, prompt_len):
    """Simulate the engine's prefill marking each full block written."""
    for j in range(prompt_len // pool.block_size):
        pool.commit_block(slot, j, None)


def test_prefix_cache_shares_full_blocks_and_defers_unready():
    pool = _prefix_pool()
    prompt = np.arange(1, 15, dtype=np.int32)          # 14 tokens: 3F + 1P
    a = pool.alloc_for(ServeRequest(prompt, max_new_tokens=2))
    # same prompt while the donor has not prefilled yet: deferred, not raced
    assert pool.alloc_for(ServeRequest(prompt.copy(), max_new_tokens=2)) \
        is None
    _commit_full_blocks(pool, a, len(prompt))
    b = pool.alloc_for(ServeRequest(prompt.copy(), max_new_tokens=2))
    assert b is not None
    # the three full prefix blocks alias; the partial tail never does
    assert list(pool.tables[b][:3]) == list(pool.tables[a][:3])
    assert pool.tables[b][3] != pool.tables[a][3]
    assert pool.cached_tokens(b) == 3 * pool.block_size
    assert pool.cached_tokens(a) == 0
    # shared blocks are counted once: 4 (donor) + 1 (tail) blocks in use
    assert pool.free_blocks == pool.n_blocks - 5


def test_prefix_cache_last_chunk_never_served_from_cache():
    """A block-aligned prompt keeps its final chunk out of the hit range —
    its logits seed the first generated token, so it must be computed."""
    pool = _prefix_pool()
    prompt = np.arange(1, 13, dtype=np.int32)          # 12 tokens: 3 full
    a = pool.alloc_for(ServeRequest(prompt, max_new_tokens=2))
    _commit_full_blocks(pool, a, len(prompt))
    b = pool.alloc_for(ServeRequest(prompt.copy(), max_new_tokens=2))
    assert pool.cached_tokens(b) == 2 * pool.block_size   # not 3
    assert pool.tables[b][2] != pool.tables[a][2]


def test_prefix_cache_refcount_free_preempt_cycles_leak_no_blocks():
    pool = _prefix_pool()
    prompt = np.arange(1, 15, dtype=np.int32)
    for cycle in range(3):
        a = pool.alloc_for(ServeRequest(prompt, max_new_tokens=2))
        _commit_full_blocks(pool, a, len(prompt))
        b = pool.alloc_for(ServeRequest(prompt.copy(), max_new_tokens=2))
        pool.free(a)                                   # donor leaves first
        pool.free(b)                                   # then the sharer
        # every block is reclaimable; the prefix blocks stay cached
        assert pool.free_blocks == pool.n_blocks
        assert pool.evictable_blocks == 3
    # a re-arrival revives the evictable blocks instead of recomputing
    c = pool.alloc_for(ServeRequest(prompt.copy(), max_new_tokens=2))
    assert pool.cached_tokens(c) == 3 * pool.block_size
    pool.free(c)
    assert pool.free_blocks == pool.n_blocks


def test_prefix_cache_eviction_reclaims_cached_blocks():
    pool = _prefix_pool(n_blocks=4)
    prompt = np.arange(1, 15, dtype=np.int32)          # needs all 4 blocks
    a = pool.alloc_for(ServeRequest(prompt, max_new_tokens=2))
    _commit_full_blocks(pool, a, len(prompt))
    pool.free(a)
    assert pool.evictable_blocks == 3
    other = np.arange(100, 114, dtype=np.int32)        # distinct content
    b = pool.alloc_for(ServeRequest(other, max_new_tokens=2))
    assert b is not None and pool.cached_tokens(b) == 0
    assert pool.evictable_blocks == 0                  # cache was evicted
    pool.free(b)
    assert pool.free_blocks == pool.n_blocks


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_prefix_hit_prefill_token_identical_to_cold(arch):
    """Shared-prefix requests served with the prefix cache on must be
    token-for-token identical to cold contiguous-static serving, while a
    majority of their prompt blocks come from the cache (dense / moe — the
    carried expert-counts snapshot — / vlm)."""
    cfg = get_config(arch, smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    rng = np.random.default_rng(11)
    common = rng.integers(1, cfg.vocab_size, size=12).astype(np.int32)

    def reqs():
        r = np.random.default_rng(12)
        return [ServeRequest(
            np.concatenate([common,
                            r.integers(1, cfg.vocab_size,
                                       size=3 + i).astype(np.int32)]),
            max_new_tokens=4) for i in range(4)]

    cold, _ = ServeEngine(cfg, params=params, max_len=32).run(reqs())
    warm, stats = ServeEngine(cfg, params=params, max_len=32, n_slots=4,
                              cache="paged", block_size=4).run(reqs())
    for a, b in zip(cold, warm):
        assert a.output == b.output
    assert stats.prefix_blocks_hit > 0
    assert stats.prefix_hit_rate >= 0.5


def test_prefix_cache_off_is_hit_free_and_identical():
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    prompt = np.arange(1, 14, dtype=np.int32)
    reqs = lambda: [ServeRequest(prompt.copy(), max_new_tokens=4)
                    for _ in range(3)]
    on, s_on = ServeEngine(cfg, params=params, max_len=32, n_slots=3,
                           cache="paged", block_size=4).run(reqs())
    off, s_off = ServeEngine(cfg, params=params, max_len=32, n_slots=3,
                             cache="paged", block_size=4,
                             prefix_cache=False).run(reqs())
    for a, b in zip(on, off):
        assert a.output == b.output
    assert s_on.prefix_blocks_hit > 0
    assert s_off.prefix_blocks_hit == 0 and s_off.prefix_blocks_total == 0


# ---------------------------------------------------------------------------
# batched prefill lanes
# ---------------------------------------------------------------------------
def test_batched_prefill_one_dispatch_per_chunk_round():
    """N equal-length requests joining together must prefill in
    O(chunk-rounds) dispatches at N lanes — not O(N x chunks) — and still
    match single-lane serving token for token."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    lengths = [12, 12, 12, 12]                       # 3 chunks each at bs=4
    reqs = lambda: _requests(cfg, lengths, max_new=3)

    wide, sw = ServeEngine(cfg, params=params, max_len=32, n_slots=4,
                           cache="paged", block_size=4, prefix_cache=False,
                           prefill_lanes=4).run(reqs())
    narrow, sn = ServeEngine(cfg, params=params, max_len=32, n_slots=4,
                             cache="paged", block_size=4, prefix_cache=False,
                             prefill_lanes=1).run(reqs())
    for a, b in zip(wide, narrow):
        assert a.output == b.output
    assert sw.prefill_dispatches == 3                # one per chunk round
    assert sn.prefill_dispatches == 12               # one per request-chunk


def test_batched_prefill_mixed_lengths_lane_refill():
    """Lanes refill from the queue as short prompts finish, and padded tail
    chunks never perturb outputs (pad positions write no K/V)."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    lengths = [13, 2, 7, 5, 11, 3]
    static, _ = ServeEngine(cfg, params=params, max_len=32).run(
        _requests(cfg, lengths))
    lanes, st = ServeEngine(cfg, params=params, max_len=32, n_slots=6,
                            cache="paged", block_size=4,
                            prefill_lanes=2).run(_requests(cfg, lengths))
    for a, b in zip(static, lanes):
        assert a.output == b.output
    assert st.prefill_dispatches < sum(-(-s // 4) for s in lengths)


# ---------------------------------------------------------------------------
# dispatch/time split accounting
# ---------------------------------------------------------------------------
def test_stats_phase_split_and_dispatch_counts():
    cfg = get_config("llama3.2-1b", smoke=True)
    _, st = ServeEngine(cfg, max_len=32, n_slots=2, cache="paged",
                        block_size=4).run(_requests(cfg, [5, 6], max_new=3))
    assert st.prefill_dispatches > 0 and st.decode_dispatches > 0
    assert st.prefill_s > 0.0 and st.decode_s > 0.0
    # multi-step horizons: one jitted dispatch covers up to K decode steps
    assert st.decode_horizon == 8
    assert st.decode_dispatches <= st.steps
    assert st.host_syncs > 0
    one, s1 = ServeEngine(cfg, max_len=32, n_slots=2, cache="paged",
                          block_size=4, decode_horizon=1).run(
        _requests(cfg, [5, 6], max_new=3))
    assert s1.decode_dispatches == s1.steps      # K=1 is the classic loop


@pytest.mark.parametrize("k", [1, 3, 8])
def test_paged_horizon_token_identity_under_churn(k):
    """Paged K-step horizons with admission churn, mid-horizon finishes,
    and block growth across horizon boundaries must stay token-identical
    to the contiguous static reference."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    lengths, arrivals = [5, 3, 8, 2, 6], [0.0, 0.0, 1.0, 3.0, 4.0]
    budgets = [2, 9, 4, 7, 1]

    def reqs(with_arrivals):
        rs = _requests(cfg, lengths, arrivals if with_arrivals else None)
        for r, b in zip(rs, budgets):
            r.max_new_tokens = b
        return rs

    static, _ = ServeEngine(cfg, params=params, max_len=32,
                            decode_horizon=1).run(reqs(False))
    paged, st = ServeEngine(cfg, params=params, max_len=32, n_slots=3,
                            cache="paged", block_size=4,
                            decode_horizon=k).run(reqs(True))
    for a, b in zip(static, paged):
        assert a.output == b.output
    if k > 1:
        assert st.decode_dispatches < st.steps


def test_paged_horizon_shrinks_before_preempting():
    """A pool too tight to pre-allocate K=8 steps of growth must shrink the
    horizon (down to the classic one-step loop) rather than thrash through
    avoidable preemptions — and still match the static reference."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    reqs = lambda: _requests(cfg, [8, 8], max_new=8)
    static, _ = ServeEngine(cfg, params=params, max_len=32,
                            decode_horizon=1).run(reqs())
    # 6 blocks of 4 cannot hold both requests at 16 tokens: the K=1 engine
    # preempts; the K=8 engine must behave identically at the same pool.
    paged, st = ServeEngine(cfg, params=params, max_len=32, n_slots=2,
                            cache="paged", block_size=4, n_blocks=6,
                            watermark=0.0, decode_horizon=8).run(reqs())
    assert st.preemptions >= 1
    for a, b in zip(static, paged):
        assert a.output == b.output


def test_deferred_sharer_does_not_block_unrelated_admission():
    """A request deferred behind a mid-prefill donor parks only itself:
    unrelated admissible requests behind it in FCFS order still admit in
    the same round (deferral is not pool exhaustion)."""
    from repro.serve import ContinuousScheduler
    pool = _prefix_pool()
    sched = ContinuousScheduler(pool)
    x = np.arange(1, 15, dtype=np.int32)
    y = np.arange(50, 64, dtype=np.int32)
    a = ServeRequest(x, max_new_tokens=2)
    b = ServeRequest(x.copy(), max_new_tokens=2)     # shares a's prefix
    c = ServeRequest(y, max_new_tokens=2)            # unrelated
    for r in (a, b, c):
        sched.submit(r)
    admitted = sched.admit()
    assert a in admitted and c in admitted and b not in admitted
    _commit_full_blocks(pool, a.slot, len(x))
    assert sched.admit() == [b]
    assert pool.cached_tokens(b.slot) == 3 * pool.block_size
