"""Deterministic fallback for ``hypothesis`` when it is not installed.

The test image does not always ship hypothesis and this repo must not add
dependencies, so the property tests import through this shim:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from tests._compat import given, settings, st

The shim replays each property test over ``max_examples`` pseudo-random
draws from the declared strategies, seeded per test name — deterministic
across runs, no shrinking, but the same example volume as the hypothesis
profiles used here. Only the strategy surface these tests use is provided
(integers, floats, sampled_from, lists).
"""
from __future__ import annotations

import random
import zlib
from typing import Any, Callable, Dict


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda r: r.choice(options))

    @staticmethod
    def lists(elements: _Strategy, *, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(r: random.Random):
            n = r.randint(min_size, max_size)
            return [elements.example(r) for _ in range(n)]
        return _Strategy(draw)


st = _Strategies()


def settings(*, max_examples: int = 10, **_ignored):
    """Record ``max_examples`` on the (already given-wrapped) test."""
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(**strategies: _Strategy):
    """Replay the test over deterministic draws from ``strategies``."""
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_compat_max_examples", 10)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn: Dict[str, Any] = {
                    name: strat.example(rng)
                    for name, strat in strategies.items()
                }
                fn(**drawn)
        # NOT functools.wraps: the wrapper must present a zero-arg signature
        # or pytest resolves the strategy kwargs as fixtures.
        for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
            setattr(wrapper, attr, getattr(fn, attr))
        return wrapper
    return deco
