"""Per-architecture smoke tests.

For every assigned architecture: instantiate the REDUCED variant of the same
family (<=2 layers, d_model<=512, <=4 experts), run one forward pass + one
train step on CPU, and assert output shapes + finiteness. Decode paths get a
smoke test too (3 decode steps match the prefill logits trajectory loosely).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.api import build_model, make_batch

BATCH, SEQ = 2, 64


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_smoke_config_is_reduced(arch_setup):
    cfg, _, _ = arch_setup
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


def test_forward_shapes_and_finite(arch_setup):
    cfg, model, params = arch_setup
    batch = make_batch(cfg, BATCH, SEQ, jax.random.key(1))
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_step_no_nans(arch_setup):
    cfg, model, params = arch_setup
    batch = make_batch(cfg, BATCH, SEQ, jax.random.key(2))

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), f"{cfg.arch_id}: loss={loss}"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    # loss near ln(vocab) at init (random labels)
    assert 0.1 * jnp.log(cfg.vocab_size) < loss < 3.0 * jnp.log(cfg.vocab_size)


def test_decode_step_shapes(arch_setup):
    cfg, model, params = arch_setup
    cache = model.init_cache(BATCH, SEQ)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache2 = step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)
    logits, _ = step(params, cache2, tok, jnp.int32(1))
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_decode_matches_forward_prefix():
    """Greedy decode logits must match teacher-forced forward logits (dense)."""
    cfg = get_config("llama3.2-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (1, 8), 0, cfg.vocab_size)
    full = model.forward(params, {"tokens": toks})

    cache = model.init_cache(1, 8)
    step = jax.jit(model.decode_step)
    for t in range(8):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        assert jnp.allclose(logits[0, 0], full[0, t], atol=2e-3), f"pos {t}"


def test_decode_matches_forward_prefix_ssm():
    """Recurrent decode must match the chunked-SSD training forward (mamba2)."""
    cfg = get_config("mamba2-780m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(4), (1, 8), 0, cfg.vocab_size)
    full = model.forward(params, {"tokens": toks})

    cache = model.init_cache(1, 8)
    step = jax.jit(model.decode_step)
    for t in range(8):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        assert jnp.allclose(logits[0, 0], full[0, t], atol=2e-3), f"pos {t}"
