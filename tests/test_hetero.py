"""Heterogeneous-cluster extension (paper Appendix A.2)."""
import pytest

from repro.core.cluster import ServerSpec
from repro.core.hetero import MachineType, solve_hetero
from repro.core.trace import TraceConfig, generate

TYPES = [
    MachineType("v100", n_machines=2, spec=ServerSpec(8, 24.0, 500.0),
                gpu_speed=1.0),
    MachineType("a100", n_machines=1, spec=ServerSpec(8, 48.0, 1000.0),
                gpu_speed=2.0),
]


def _jobs(n=10, seed=0):
    # runnable set: total GPU demand must fit the 24-GPU hetero cluster
    # (the paper's round admission guarantees sum g_j <= G)
    jobs = generate(TraceConfig(n_jobs=3 * n, split=(40, 40, 20),
                                arrival="static", seed=seed,
                                multi_gpu=False))
    return jobs[:n]


def test_hetero_solves_and_dominates_fair():
    jobs = _jobs(8)
    res = solve_hetero(jobs, TYPES, time_limit=20.0)
    assert res.alloc, "solver returned no allocation"
    assert res.throughput >= res.fair_throughput - 1e-6
    # every job placed on exactly one type
    assert set(res.alloc) == {j.job_id for j in jobs}
    for t, c, m in res.alloc.values():
        assert t in ("v100", "a100")
        assert c >= 1 and m >= 0


def test_hetero_prefers_fast_type_for_compute_bound():
    """GPU-bound jobs (language) should gravitate to the faster generation
    when capacity allows."""
    jobs = [j for j in _jobs(16, seed=3)]
    lang = [j for j in jobs if j.model_name in ("gnmt", "lstm", "transformer-xl")]
    if not lang:
        pytest.skip("no language jobs in this seed")
    res = solve_hetero(jobs, TYPES, time_limit=20.0)
    assert res.alloc
    # the objective beats the slowest-type fair floor (fast type exploited)
    assert res.throughput > res.fair_throughput


def test_hetero_capacity_respected():
    jobs = _jobs(12, seed=5)
    res = solve_hetero(jobs, TYPES, time_limit=20.0)
    used = {t.name: [0.0, 0.0, 0] for t in TYPES}
    for j in jobs:
        t, c, m = res.alloc[j.job_id]
        used[t][0] += c
        used[t][1] += m
        used[t][2] += j.gpu_demand
    for t in TYPES:
        assert used[t.name][0] <= t.spec.cpus * t.n_machines + 1e-6
        assert used[t.name][1] <= t.spec.mem * t.n_machines + 1e-6
        assert used[t.name][2] <= t.spec.gpus * t.n_machines
