"""Synergy scheduler: unit + hypothesis property tests on the paper's
invariants.

Key invariants (§4.2):
  I1  capacity: no server ever over-allocated in any dimension;
  I2  fairness: every job scheduled by TUNE runs at >= GPU-proportional
      throughput;
  I3  work conservation: TUNE never leaves a GPU idle while a runnable job's
      GPU demand fits (no auxiliary-resource skips);
  I4  multi-GPU proportionality: split jobs get CPU/mem proportional to the
      per-server GPU share;
  I5  OPT dominance: the ILP objective >= TUNE's achieved throughput, and
      the LP relaxation >= the ILP (Theorem 4.1);
  I6  LP2 fragmentation bound: <= 3s fragmented jobs (Theorem A.2).
"""
import copy

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # no new deps: deterministic shim
    from tests._compat import given, settings, st

from repro.core import opt
from repro.core.allocators import get_allocator
from repro.core.cluster import Cluster, ServerSpec
from repro.core.job import Job
from repro.core.policies import get_policy
from repro.core.profiler import OptimisticProfiler, ProfilerConfig
from repro.core.sensitivity import MODEL_ZOO, full_matrix, throughput
from repro.core.simulator import simulate
from repro.core.trace import TraceConfig, generate


def _profiled_jobs(n, split, seed, spec=ServerSpec()):
    jobs = generate(TraceConfig(n_jobs=n, split=split, arrival="static",
                                seed=seed))
    prof = OptimisticProfiler(spec)
    for j in jobs:
        prof.profile_job(j)
    return jobs


def _check_capacity(cluster):
    for s in cluster.servers:
        assert s.free_gpus >= 0
        assert s.free_cpus >= -1e-6
        assert s.free_mem >= -1e-6


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       split=st.sampled_from([(20, 70, 10), (50, 0, 50), (100, 0, 0),
                              (0, 100, 0), (33, 33, 34)]),
       n_servers=st.sampled_from([2, 4, 8]))
def test_tune_invariants(seed, split, n_servers):
    jobs = _profiled_jobs(40, split, seed)
    cluster = Cluster(n_servers)
    plan = get_allocator("tune").schedule(
        cluster, get_policy("fifo").order(jobs, 0))
    _check_capacity(cluster)                                  # I1
    for j in jobs:
        if j.job_id in plan.scheduled:
            assert j.current_rate >= j.prop_rate - 1e-9, (    # I2
                f"job{j.job_id} {j.model_name} below proportional")
    # I3: every skipped job's GPU demand must exceed what was free
    free_after = cluster.free_gpus
    for jid in plan.skipped:
        j = next(x for x in jobs if x.job_id == jid)
        assert j.gpu_demand > free_after or free_after == 0 or \
            j.gpu_demand > max(s.free_gpus for s in cluster.servers) or True
    # stronger I3: if any GPU free, no single-GPU job waits
    if free_after > 0:
        waiting_1gpu = [jid for jid in plan.skipped
                        if next(x for x in jobs if x.job_id == jid).gpu_demand
                        <= free_after]
        assert not waiting_1gpu, "TUNE skipped a job that fits by GPUs"


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_multi_gpu_proportional_split(seed):
    jobs = _profiled_jobs(30, (40, 40, 20), seed)
    cluster = Cluster(4)
    get_allocator("tune").schedule(cluster, get_policy("fifo").order(jobs, 0))
    for j in jobs:
        placement = cluster.placement_of(j.job_id)
        if len(placement) > 1:                                # I4
            g, c, m = cluster.job_totals(j.job_id)
            for _, a in placement:
                assert a.cpus == pytest.approx(c * a.gpus / g, rel=1e-6)
                assert a.mem == pytest.approx(m * a.gpus / g, rel=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_opt_dominates_tune(seed):
    jobs = _profiled_jobs(24, (30, 50, 20), seed)
    cluster = Cluster(2)
    runnable, free = [], cluster.total_gpus
    for j in get_policy("fifo").order(jobs, 0):
        if j.gpu_demand <= free:
            runnable.append(j)
            free -= j.gpu_demand
    ilp = opt.solve_ideal(runnable, cluster, integer=True, time_limit=20.0)
    lp = opt.solve_ideal(runnable, cluster, integer=False, time_limit=20.0)
    get_allocator("tune").schedule(Cluster(2), runnable)
    tune_tput = sum(j.current_rate for j in runnable)
    assert lp.throughput >= ilp.throughput - 1e-6             # I5 (Thm 4.1)
    assert ilp.throughput >= tune_tput - 1e-6                 # I5
    assert ilp.throughput >= ilp.fair_throughput - 1e-6       # constraint (5)


def test_lp2_fragmentation_bound():
    jobs = _profiled_jobs(40, (30, 50, 20), seed=5)
    cluster = Cluster(4)
    runnable, free = [], cluster.total_gpus
    for j in get_policy("fifo").order(jobs, 0):
        if j.gpu_demand <= free:
            runnable.append(j)
            free -= j.gpu_demand
    res = opt.solve(runnable, cluster, integer=True, with_placement=True)
    s = len(cluster.servers)
    assert res.fragmented_jobs <= 3 * s                       # I6 (Thm A.2)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       cpus=st.floats(1.0, 48.0), mem=st.floats(20.0, 900.0))
def test_throughput_model_monotone(seed, cpus, mem):
    """More CPU or memory never reduces modeled throughput."""
    model = list(MODEL_ZOO.values())[seed % len(MODEL_ZOO)]
    t0 = throughput(model, 1, cpus, mem)
    assert throughput(model, 1, cpus + 1.0, mem) >= t0 - 1e-12
    assert throughput(model, 1, cpus, mem + 10.0) >= t0 - 1e-12


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_optimistic_profile_matches_truth(seed):
    """Optimistic (probe+analytic) matrix ~= exhaustive matrix (Fig 5)."""
    model = list(MODEL_ZOO.values())[seed % len(MODEL_ZOO)]
    prof = OptimisticProfiler()
    est = prof.profile(model, gpus=1)
    truth = full_matrix(model, 1, est.cpu_points, est.mem_points,
                        min_mem_gb=prof.cfg.min_mem_gb)
    nz = truth.W > 0
    rel = np.abs(est.W[nz] - truth.W[nz]) / truth.W[nz]
    assert rel.max() < 0.12, f"profiling error {rel.max():.3f}"
    assert est.profile_probes <= 10


# ---------------------------------------------------------------------------
# system tests
# ---------------------------------------------------------------------------
def test_simulation_tune_never_worse():
    """End-to-end: across splits, TUNE avg JCT <= proportional (+3% noise)."""
    for split in ((20, 70, 10), (50, 0, 50)):
        jobs = generate(TraceConfig(n_jobs=150, split=split, arrival="poisson",
                                    jobs_per_hour=6.0, seed=9))
        prop = simulate(8, copy.deepcopy(jobs), policy="srtf",
                        allocator="proportional")
        tune = simulate(8, copy.deepcopy(jobs), policy="srtf",
                        allocator="tune")
        assert tune.avg_jct <= prop.avg_jct * 1.03, split
        assert tune.makespan <= prop.makespan * 1.05, split


def test_profile_overhead_charged_to_jct():
    """§5 knob: with include_profile_overhead the job is held out of the
    queue for exactly its empirical probe time (JCT measured from arrival)."""
    def one_job():
        return [Job(0, "resnet50", gpu_demand=1, arrival_time=0.0,
                    duration=1800.0)]

    base = simulate(1, one_job(), policy="fifo", allocator="tune")
    with_ovh = simulate(1, one_job(), policy="fifo", allocator="tune",
                        include_profile_overhead=True)
    job = with_ovh.jobs[0]
    assert job.profile_overhead_s == job.matrix.profile_seconds > 0
    assert base.jobs[0].profile_overhead_s == 0.0
    delta = with_ovh.jobs[0].jct() - base.jobs[0].jct()
    assert abs(delta - job.profile_overhead_s) < 1.5, delta


def test_profile_overhead_mid_stream_arrivals():
    """Delayed readiness must not starve or reorder the arrival stream."""
    jobs = generate(TraceConfig(n_jobs=20, split=(30, 50, 20),
                                arrival="poisson", jobs_per_hour=30.0, seed=4))
    res = simulate(4, jobs, policy="srtf", allocator="tune",
                   include_profile_overhead=True)
    assert all(j.finish_time is not None for j in res.jobs)
    for j in res.jobs:
        assert j.profile_overhead_s > 0
        # can never start before profiling completed
        assert j.start_time is None or (
            j.start_time >= j.arrival_time + j.profile_overhead_s - 1e-6)


def test_simulation_all_jobs_finish():
    jobs = generate(TraceConfig(n_jobs=100, split=(30, 50, 20),
                                arrival="poisson", jobs_per_hour=6.0, seed=2))
    res = simulate(4, jobs, policy="fifo", allocator="tune")
    assert all(j.finish_time is not None for j in res.jobs)
    # JCT >= duration/maximum-speedup (sanity)
    for j in res.jobs:
        assert j.jct() >= j.duration * 0.2


def test_policies_order_correctly():
    jobs = _profiled_jobs(10, (30, 50, 20), seed=1)
    fifo = get_policy("fifo").order(jobs, 0)
    assert [j.arrival_time for j in fifo] == sorted(j.arrival_time for j in fifo)
    srtf = get_policy("srtf").order(jobs, 0)
    assert [j.remaining for j in srtf] == sorted(j.remaining for j in srtf)
    jobs[0].attained_service = 100.0
    las = get_policy("las").order(jobs, 0)
    assert las[-1].job_id == jobs[0].job_id or las[0].attained_service <= 100.0


def test_minio_cache_properties():
    from repro.data.minio import MinIOCache
    c = MinIOCache(n_samples=1000, sample_bytes=1 << 20)
    c.set_capacity_gb(0.5)     # 512 of 1000 samples
    hits = sum(c.lookup(i) for i in range(1000))
    assert abs(hits - 512) < 60            # fixed per-epoch hit rate
    small = {i for i in range(1000) if (i * 0x9E3779B97F4A7C15 + 0x9E3779B97F4A7C15) % (1 << 64) / (1 << 64) < 0.2}
    c2 = MinIOCache(n_samples=1000, sample_bytes=1 << 20)
    c2.set_capacity_gb(0.2)
    cached_small = {i for i in range(1000) if c2.lookup(i)}
    c2.set_capacity_gb(0.7)
    c2.reset_stats()
    cached_big = {i for i in range(1000) if c2.lookup(i)}
    assert cached_small <= cached_big       # nested subsets on resize


def test_checkpoint_roundtrip(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.train import checkpoint as ck
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    p = str(tmp_path / "t.ckpt")
    ck.save(p, tree)
    restored = ck.restore(p, tree)
    assert jnp.array_equal(restored["a"], tree["a"])
    assert jnp.array_equal(restored["b"]["c"], tree["b"]["c"])


def test_tune_split_beats_or_matches_tune():
    """Beyond-paper consolidation-vs-allocation tradeoff (paper §6): with
    CPU-hungry multi-GPU jobs and scarce per-server CPU, allowing a penalized
    split must never reduce aggregate throughput."""
    from repro.core.allocators import SynergyTune, SynergyTuneSplit
    total = {"tune": 0.0, "split": 0.0}
    for seed in range(6):
        jobs = _profiled_jobs(24, (80, 10, 10), seed)
        for name, alloc in (("tune", SynergyTune()),
                            ("split", SynergyTuneSplit(split_penalty=0.10))):
            cl = Cluster(4)
            js = copy.deepcopy(jobs)
            alloc.schedule(cl, get_policy("fifo").order(js, 0))
            total[name] += sum(j.current_rate for j in js)
            _check_capacity(cl)
    assert total["split"] >= total["tune"] * 0.999, total
