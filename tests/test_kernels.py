"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode) + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # no new deps: deterministic shim
    from tests._compat import given, settings, st

from repro.kernels import ops, ref


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s", [64, 128, 320])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, hq, hkv, dtype):
    ks = jax.random.split(jax.random.key(s * hq + hkv), 3)
    d, b = 64, 2
    q = _rand(ks[0], (b, s, hq, d), dtype)
    k = _rand(ks[1], (b, s, hkv, d), dtype)
    v = _rand(ks[2], (b, s, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    exp = ref.attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.key(window), 3)
    b, s, h, d = 1, 128, 4, 64
    q, k, v = (_rand(ks[i], (b, s, h, d)) for i in range(3))
    out = ops.flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64)
    exp = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([32, 64, 96]),
       seed=st.integers(0, 2 ** 16))
def test_flash_attention_property_rowsum(sq, seed):
    """Softmax invariance: attention output of constant V is constant."""
    ks = jax.random.split(jax.random.key(seed), 2)
    b, h, d = 1, 2, 32
    q = _rand(ks[0], (b, sq, h, d))
    k = _rand(ks[1], (b, sq, h, d))
    v = jnp.ones((b, sq, h, d))
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    np.testing.assert_allclose(out, jnp.ones_like(out), atol=1e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("window", [0, 5])
def test_paged_attention_kernel_vs_ref(hq, hkv, window):
    """Block-table gather + online softmax over valid blocks only == the
    pure-jnp paged oracle, across GQA group sizes and sliding windows."""
    ks = jax.random.split(jax.random.key(hq * 31 + hkv + window), 3)
    nb, bs, d, b, mb = 10, 8, 32, 3, 4
    kp = _rand(ks[0], (nb, bs, hkv, d))
    vp = _rand(ks[1], (nb, bs, hkv, d))
    q = _rand(ks[2], (b, hq, d))
    tables = jnp.array([[3, 7, -1, -1], [0, 1, 2, 9], [5, -1, -1, -1]],
                       jnp.int32)
    pos = jnp.array([12, 30, 2], jnp.int32)
    out = ops.paged_attention(q, kp, vp, tables, pos, window)
    exp = ref.paged_attention(q, kp, vp, tables, pos, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_matches_contiguous_layout():
    """Paging a contiguous K/V prefix through an arbitrary block table gives
    the same answer as dense decode attention over that prefix."""
    ks = jax.random.split(jax.random.key(11), 3)
    bs, h, d, s = 4, 2, 16, 13
    mb = 4
    kc = _rand(ks[0], (1, mb * bs, h, d))
    vc = _rand(ks[1], (1, mb * bs, h, d))
    q = _rand(ks[2], (1, h, d))
    perm = jnp.array([5, 0, 3, 7], jnp.int32)        # scattered block homes
    kp = jnp.zeros((8, bs, h, d)).at[perm].set(kc[0].reshape(mb, bs, h, d))
    vp = jnp.zeros((8, bs, h, d)).at[perm].set(vc[0].reshape(mb, bs, h, d))
    out = ops.paged_attention(q, kp, vp, perm[None], jnp.array([s], jnp.int32))
    logits = jnp.einsum("bhd,bkhd->bhk", q, kc) / np.sqrt(d)
    logits = jnp.where(jnp.arange(mb * bs)[None, None] <= s, logits, -1e30)
    exp = jnp.einsum("bhk,bkhd->bhd", jax.nn.softmax(logits, axis=-1), vc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_paged_attention_property_rowsum(seed):
    """Softmax invariance: paged attention over constant V is constant, no
    matter how the blocks are scattered or how much padding the table has."""
    ks = jax.random.split(jax.random.key(seed), 2)
    nb, bs, h, d, b = 6, 4, 2, 16, 2
    kp = _rand(ks[0], (nb, bs, h, d))
    vp = jnp.ones((nb, bs, h, d))
    q = _rand(ks[1], (b, 2 * h, d))
    tables = jnp.array([[2, 4, -1], [1, -1, -1]], jnp.int32)
    pos = jnp.array([6, 1], jnp.int32)
    out = ops.paged_attention(q, kp, vp, tables, pos)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32), (96, 32), (256, 128)])
@pytest.mark.parametrize("n,p", [(16, 32), (64, 64)])
def test_ssd_scan_sweep(s, chunk, n, p):
    ks = jax.random.split(jax.random.key(s + n), 4)
    b, h = 2, 3
    xdt = _rand(ks[0], (b, s, h, p))
    a_log = -jax.nn.softplus(_rand(ks[1], (b, s, h)))
    B = _rand(ks[2], (b, s, h, n)) * 0.5
    C = _rand(ks[3], (b, s, h, n)) * 0.5
    y = ops.ssd_scan(xdt, a_log, B, C, chunk=chunk)
    ye = ref.ssd(xdt, a_log, B, C)
    np.testing.assert_allclose(y, ye, atol=5e-4, rtol=5e-4)


def test_ssd_matches_model_chunked_path():
    """Kernel == the model's jnp chunked implementation == naive recurrence."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(jax.random.key(0), 4)
    b, s, h, p, n = 1, 64, 2, 16, 8
    xdt = _rand(ks[0], (b, s, h, p))
    a_log = -jax.nn.softplus(_rand(ks[1], (b, s, h)))
    B = _rand(ks[2], (b, s, h, n))
    C = _rand(ks[3], (b, s, h, n))
    naive = ref.ssd(xdt, a_log, B, C)
    chunked = ssd_chunked(xdt, a_log, B, C, chunk=16)
    kern = ops.ssd_scan(xdt, a_log, B, C, chunk=16)
    np.testing.assert_allclose(chunked, naive, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(kern, naive, atol=2e-4, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_ssd_property_decay_zero_state(seed):
    """With a_log = -inf-ish (full decay), output reduces to C.B x per step."""
    ks = jax.random.split(jax.random.key(seed), 3)
    b, s, h, p, n = 1, 32, 1, 8, 4
    xdt = _rand(ks[0], (b, s, h, p))
    B = _rand(ks[1], (b, s, h, n))
    C = _rand(ks[2], (b, s, h, n))
    a_log = jnp.full((b, s, h), -40.0)
    y = ops.ssd_scan(xdt, a_log, B, C, chunk=8)
    exp = jnp.einsum("bshn,bshn,bshp->bshp",
                     C, B, xdt)                      # memoryless
    np.testing.assert_allclose(y, exp, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g,c,k,n", [(2, 64, 64, 64), (4, 96, 32, 80),
                                     (8, 128, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(g, c, k, n, dtype):
    ks = jax.random.split(jax.random.key(g * c), 2)
    x = _rand(ks[0], (g, c, k), dtype)
    w = _rand(ks[1], (g, k, n), dtype)
    out = ops.grouped_matmul(x, w)
    exp = ref.grouped_matmul(x, w)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       valid=st.lists(st.integers(0, 64), min_size=3, max_size=3))
def test_grouped_matmul_property_valid_rows(seed, valid):
    """Rows beyond valid_rows never contribute to the output."""
    ks = jax.random.split(jax.random.key(seed), 2)
    g, c, k, n = 3, 64, 32, 16
    x = _rand(ks[0], (g, c, k))
    w = _rand(ks[1], (g, k, n))
    vr = jnp.asarray(valid, jnp.int32)
    out = ops.grouped_matmul(x, w, vr)
    exp = ref.grouped_matmul(x, w, vr)
    np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)
    for gi, v in enumerate(valid):
        assert bool(jnp.all(out[gi, v:] == 0.0))


# ---------------------------------------------------------------------------
# kernels inside models (use_pallas=True path)
# ---------------------------------------------------------------------------
def test_model_with_pallas_attention_matches():
    from repro.configs import get_config
    from repro.models.api import build_model, make_batch
    cfg = get_config("llama3.2-1b", smoke=True)
    batch = make_batch(cfg, 2, 64, jax.random.key(1))
    m0 = build_model(cfg)
    params = m0.init(jax.random.key(0))
    l0 = m0.forward(params, batch)
    m1 = build_model(cfg.replace(use_pallas=True))
    l1 = m1.forward(params, batch)
    np.testing.assert_allclose(l0, l1, atol=2e-3, rtol=2e-3)


def test_model_with_pallas_ssd_matches():
    from repro.configs import get_config
    from repro.models.api import build_model, make_batch
    cfg = get_config("mamba2-780m", smoke=True)
    batch = make_batch(cfg, 2, 64, jax.random.key(1))
    m0 = build_model(cfg)
    params = m0.init(jax.random.key(0))
    l0 = m0.forward(params, batch)
    m1 = build_model(cfg.replace(use_pallas=True))
    l1 = m1.forward(params, batch)
    np.testing.assert_allclose(l0, l1, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# paged prefill (multi-token chunk through the block table)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("window", [0, 5])
def test_paged_prefill_kernel_vs_ref(hq, hkv, window):
    """C-token chunk attention with causal masking inside the chunk == the
    pure-jnp paged prefill oracle, across GQA group sizes and windows."""
    ks = jax.random.split(jax.random.key(hq * 37 + hkv + window), 3)
    nb, bs, d, b, mb, c = 10, 8, 32, 3, 4, 6
    kp = _rand(ks[0], (nb, bs, hkv, d))
    vp = _rand(ks[1], (nb, bs, hkv, d))
    q = _rand(ks[2], (b, c, hq, d))
    tables = jnp.array([[3, 7, -1, -1], [0, 1, 2, 9], [5, 6, -1, -1]],
                       jnp.int32)
    start = jnp.array([8, 24, 2], jnp.int32)     # chunks mid-table
    out = ops.paged_prefill_attention(q, kp, vp, tables, start, window)
    exp = ref.paged_prefill_attention(q, kp, vp, tables, start, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_paged_prefill_kernel_causal_inside_chunk():
    """Each query position in the chunk must ignore later in-chunk K/V: the
    chunk's first query row equals single-token decode at that position."""
    ks = jax.random.split(jax.random.key(5), 3)
    nb, bs, h, d, c = 6, 4, 2, 16, 4
    kp = _rand(ks[0], (nb, bs, h, d))
    vp = _rand(ks[1], (nb, bs, h, d))
    q = _rand(ks[2], (1, c, h, d))
    tables = jnp.array([[2, 0, -1]], jnp.int32)
    start = jnp.array([4], jnp.int32)
    chunk = ops.paged_prefill_attention(q, kp, vp, tables, start)
    single = ops.paged_attention(q[:, 0], kp, vp, tables, start)
    np.testing.assert_allclose(np.asarray(chunk[:, 0]), np.asarray(single),
                               atol=2e-5, rtol=2e-5)
