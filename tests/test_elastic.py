"""Elastic serving tests: ScalePlan validation, the threshold controller's
decisions and cooldown, reactive device_fail/device_join reshapes with
token identity and block-audit conservation, grow_physical migration past
the constructed pool, hold-don't-drop admission against scheduled
restores, tenant re-planning at reshape boundaries, and the
rescaled_reserves edge cases (zero-headroom tenants, single tenant,
over-committed reserves, tie-break determinism)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.obs import MetricsRegistry, Tracer, validate_events
from repro.serve import (ElasticController, FaultInjector, FaultSchedule,
                         ScalePlan, ServeEngine, ServeRequest, Tenant,
                         TenantAllocation, TenantRegistry, TenantShare,
                         run_replay)
from repro.serve.elastic import pool_capacity


def _requests(cfg, lengths, arrivals=None, max_new=5, seed=5, tenants=None):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0.0] * len(lengths)
    tenants = tenants or ["default"] * len(lengths)
    return [ServeRequest(rng.integers(1, cfg.vocab_size, size=s)
                         .astype(np.int32),
                         max_new_tokens=max_new, arrival_time=a, tenant=t)
            for s, a, t in zip(lengths, arrivals, tenants)]


def _chaos_engine(cfg, spec, seed=0, **kw):
    inj = FaultInjector(FaultSchedule.from_spec(spec, seed=seed))
    kw.setdefault("cache", "paged")
    kw.setdefault("block_size", 8)
    kw.setdefault("decode_horizon", 4)
    return ServeEngine(cfg, max_len=32, n_slots=3, injector=inj, **kw)


class _Pool:
    """Capacity-only pool stand-in for controller decision tests."""

    def __init__(self, n_blocks, free_blocks=None):
        self.n_blocks = n_blocks
        self.free_blocks = n_blocks if free_blocks is None else free_blocks


# ---------------------------------------------------------------------------
# ScalePlan + controller decisions
# ---------------------------------------------------------------------------
def test_scale_plan_validation():
    p = ScalePlan(kind="scale_up", units=4, reason="occupancy")
    assert p.dmult is None
    # a pure mesh re-bucket moves zero units but must carry a dmult
    ScalePlan(kind="scale_up", units=0, reason="device_join", dmult=8)
    with pytest.raises(ValueError, match="unknown scale kind"):
        ScalePlan(kind="sideways", units=4, reason="occupancy")
    with pytest.raises(ValueError, match="negative"):
        ScalePlan(kind="scale_up", units=-1, reason="occupancy")
    with pytest.raises(ValueError, match="move units or change dmult"):
        ScalePlan(kind="scale_down", units=0, reason="occupancy")


def test_controller_thresholds():
    m = MetricsRegistry()
    ctl = ElasticController(queue_hi=4, step_units=8, max_units=32,
                            min_units=8, cooldown=0.0)
    pool = _Pool(16)
    # no boundary sampled yet: never scale before the run starts decoding
    assert ctl.decide(0, pool, m) is None
    m.gauge("occupancy").set(0.95)
    m.gauge("queue_depth").set(0)
    up = ctl.decide(1, pool, m)
    assert (up.kind, up.reason, up.units) == ("scale_up", "occupancy", 8)
    # growth is capped at max_units total capacity
    assert ctl.decide(2, _Pool(30), m).units == 2
    assert ctl.decide(3, _Pool(32), m) is None
    # queue depth alone triggers growth at moderate occupancy
    m.gauge("occupancy").set(0.5)
    m.gauge("queue_depth").set(4)
    assert ctl.decide(4, pool, m).reason == "queue_depth"
    # exhausted slack on any tenant triggers growth
    m.gauge("queue_depth").set(0)
    m.gauge("slack[lat]").set(-2.0)
    assert ctl.decide(5, pool, m).reason == "slack"
    m.gauge("slack[lat]").set(9.0)
    # idle pool shrinks, floored at min_units AND at held blocks
    m.gauge("occupancy").set(0.05)
    down = ctl.decide(6, pool, m)
    assert (down.kind, down.units) == ("scale_down", 8)
    # 14 of 16 blocks held: shrink stops at the held floor, not min_units
    assert ctl.decide(7, _Pool(16, free_blocks=2), m).units == 2
    assert ctl.decide(8, _Pool(16, free_blocks=0), m) is None  # fully held
    assert ctl.decide(9, _Pool(8), m) is None                  # at the floor
    # a queued request vetoes the shrink
    m.gauge("queue_depth").set(1)
    assert ctl.decide(10, pool, m) is None


def test_controller_cooldown_shared_and_reset():
    m = MetricsRegistry()
    m.gauge("occupancy").set(0.99)
    m.gauge("queue_depth").set(0)
    ctl = ElasticController(step_units=4, max_units=32, cooldown=10.0)
    pool = _Pool(16)
    assert ctl.decide(0, pool, m) is not None
    # an APPLIED reshape (reactive or proactive) starts the cooldown
    ctl.note_scale(0, ScalePlan(kind="scale_down", units=4,
                                reason="device_fail"))
    assert ctl.decide(5, pool, m) is None
    assert ctl.decide(10, pool, m) is not None
    assert ctl.decisions == [("scale_down", "device_fail", 0.0)]
    ctl.reset()
    assert ctl.decisions == [] and ctl.decide(0, pool, m) is not None
    # limits bind to the first capacity seen when left unset
    fresh = ElasticController()
    assert fresh.pending_units(_Pool(12)) == 0
    assert fresh.max_units == 12 and fresh.min_units == 12
    with pytest.raises(ValueError, match="occupancy_lo"):
        ElasticController(occupancy_lo=0.9, occupancy_hi=0.5)
    with pytest.raises(ValueError, match="step_units"):
        ElasticController(step_units=0)


# ---------------------------------------------------------------------------
# reactive reshapes: device_fail / device_join on the live engine
# ---------------------------------------------------------------------------
def test_device_fail_join_token_identical_and_audited():
    cfg = get_config("llama3.2-1b", smoke=True)
    lengths, arrivals = [9, 12, 10, 8], [0, 0, 4, 6]
    eng = _chaos_engine(cfg, "device_fail@3:blocks=6:restore_after=4",
                        n_blocks=12, tracer=Tracer())
    reqs = _requests(cfg, lengths, arrivals=arrivals, max_new=6)
    res = run_replay(eng, reqs, verify=True, ref_cfg=cfg, ref_max_len=32)
    assert {k for k, _ in res.faults} == {"device_fail", "device_join"}
    assert res.stats.scale_downs == 1 and res.stats.scale_ups == 1
    assert res.stats.dropped == 0
    assert res.verified and not res.mismatched
    eng.pool.audit()
    assert not validate_events(list(eng.tracer.events))
    evs = {e["ev"] for e in eng.tracer.events}
    assert {"scale_up", "scale_down"} <= evs


def test_device_join_grows_past_pool_and_migrates():
    cfg = get_config("llama3.2-1b", smoke=True)
    # join grants MORE capacity than the pool was built with: the engine
    # must grow_physical and migrate every live KV block, token-identical.
    eng = _chaos_engine(cfg, "device_join@3:blocks=8", n_blocks=8,
                        tracer=Tracer())
    reqs = _requests(cfg, [9, 12, 10], max_new=6)
    res = run_replay(eng, reqs, verify=True, ref_cfg=cfg, ref_max_len=32)
    assert res.verified and res.stats.dropped == 0
    assert eng.pool.n_blocks == 16 and eng.pool._total_blocks >= 16
    assert res.stats.migrated_blocks > 0
    eng.pool.audit()
    migrates = [e for e in eng.tracer.events if e["ev"] == "migrate"]
    assert migrates and migrates[0]["added"] >= 1
    assert migrates[0]["blocks"] == res.stats.migrated_blocks


def test_hold_until_restore_drops_nothing():
    cfg = get_config("llama3.2-1b", smoke=True)
    # the no-restore twin of this schedule drops late arrivals
    # (test_pool_shrink_drops_score_separately); with a scheduled join the
    # admission path must HOLD them against pending capacity instead.
    eng = _chaos_engine(cfg, "device_fail@2:blocks=10:restore_after=4",
                        n_blocks=12, max_admit_retries=2)
    reqs = _requests(cfg, [9, 12, 10, 11], arrivals=[0, 0, 6, 6], max_new=4)
    res = run_replay(eng, reqs, verify=True, ref_cfg=cfg, ref_max_len=32)
    assert res.stats.dropped == 0 and not res.dropped
    assert res.stats.scale_ups == 1        # the join landed mid-run
    assert res.verified and not res.mismatched
    eng.pool.audit()


def test_proactive_scale_up_is_exact():
    cfg = get_config("llama3.2-1b", smoke=True)
    # start well under the ceiling with a deep queue: the controller must
    # reclaim capacity proactively without disturbing greedy outputs.
    ctl = ElasticController(queue_hi=2, step_units=8, max_units=16,
                            cooldown=2.0)
    inj = FaultInjector(FaultSchedule())
    eng = ServeEngine(cfg, max_len=32, n_slots=3, cache="paged",
                      block_size=8, n_blocks=8, decode_horizon=2,
                      injector=inj, elastic=ctl, tracer=Tracer())
    reqs = _requests(cfg, [9, 12, 10, 8, 11], max_new=6)
    res = run_replay(eng, reqs, verify=True, ref_cfg=cfg, ref_max_len=32)
    assert res.stats.scale_ups >= 1
    assert any(r == "queue_depth" or r == "occupancy"
               for _, r, _ in ctl.decisions)
    assert res.verified and res.stats.dropped == 0
    eng.pool.audit()


def test_reshape_replans_tenant_allocation():
    cfg = get_config("llama3.2-1b", smoke=True)
    reg = TenantRegistry([Tenant("lat", weight=2.0, slo_steps=24.0),
                          Tenant("batch")])
    eng = _chaos_engine(cfg, "device_fail@3:blocks=4:restore_after=4",
                        n_blocks=12, tenants=reg, policy="slo")
    reqs = _requests(cfg, [9, 12, 10, 8], max_new=5,
                     tenants=["batch", "lat", "batch", "lat"])
    out, st = eng.run(reqs)
    # every applied reshape re-profiles the live classes and re-plans
    assert st.replans == st.scale_ups + st.scale_downs == 2
    assert eng.allocation is not None
    assert set(eng.allocation.shares) <= {"batch", "lat"}
    assert sum(eng.pool.tenant_reserves.values()) <= eng.pool.n_blocks
    assert st.dropped == 0
    eng.pool.audit()


def test_elastic_run_is_repeatable():
    cfg = get_config("llama3.2-1b", smoke=True)

    def once():
        ctl = ElasticController(queue_hi=2, step_units=4, max_units=16,
                                cooldown=2.0)
        eng = _chaos_engine(cfg, "device_fail@2:blocks=6:restore_after=4",
                            n_blocks=16, elastic=ctl, decode_horizon=2)
        out, st = eng.run(_requests(cfg, [9, 12, 10, 8], max_new=5))
        return ([r.output for r in out], list(ctl.decisions),
                (st.scale_ups, st.scale_downs, st.replans))

    assert once() == once()


# ---------------------------------------------------------------------------
# rescaled_reserves edge cases
# ---------------------------------------------------------------------------
def test_rescaled_reserves_zero_headroom_tenant():
    alloc = TenantAllocation(
        shares={"a": TenantShare("a", units=8, k_cap=4, lanes=2, headroom=6),
                "z": TenantShare("z", units=8, k_cap=4, lanes=2, headroom=0)},
        total_units=16, max_k=8)
    for total in (16, 8, 3, 0):
        out = alloc.rescaled_reserves(total)
        assert out["z"] == 0                 # zero stays zero at every scale
    assert alloc.rescaled_reserves(8)["a"] == 3


def test_rescaled_reserves_single_tenant():
    alloc = TenantAllocation(
        shares={"solo": TenantShare("solo", units=16, k_cap=8, lanes=4,
                                    headroom=5)},
        total_units=16, max_k=8)
    assert alloc.rescaled_reserves(16) == {"solo": 5}
    assert alloc.rescaled_reserves(8) == {"solo": 2}   # round(2.5) -> 2
    assert alloc.rescaled_reserves(1) == {"solo": 0}
    assert alloc.rescaled_reserves(64) == {"solo": 5}  # frac capped at 1.0


def test_rescaled_reserves_overcommit_clamped_to_pool():
    # a hand-built allocation can promise more headroom than the pool has;
    # the backstop trims the largest reserves first so admission never
    # waits on blocks that cannot exist.
    alloc = TenantAllocation(
        shares={"a": TenantShare("a", units=4, k_cap=4, lanes=1, headroom=7),
                "b": TenantShare("b", units=4, k_cap=4, lanes=1, headroom=3)},
        total_units=8, max_k=8)
    out = alloc.rescaled_reserves(6)
    assert sum(out.values()) <= 6
    assert out["a"] >= out["b"]
    assert alloc.rescaled_reserves(2) in ({"a": 2, "b": 0}, {"a": 1, "b": 1})
    assert sum(alloc.rescaled_reserves(0).values()) == 0


def test_rescaled_reserves_tiebreak_is_order_free():
    shares = {t: TenantShare(t, units=4, k_cap=4, lanes=1, headroom=3)
              for t in ("b", "a", "c")}
    fwd = TenantAllocation(shares=shares, total_units=12, max_k=8)
    rev = TenantAllocation(
        shares={t: shares[t] for t in sorted(shares, reverse=True)},
        total_units=12, max_k=8)
    # 3 tenants * 3 * 0.5 = 4.5 units: the odd unit must land on the same
    # tenant regardless of dict insertion order
    assert fwd.rescaled_reserves(6) == rev.rescaled_reserves(6)
    out = fwd.rescaled_reserves(6)
    assert sum(out.values()) in (4, 5) and max(out.values()) == 2


def test_pool_capacity_both_backends():
    from repro.serve import CachePool
    from repro.models.api import build_model
    model = build_model(get_config("llama3.2-1b", smoke=True))
    pool = CachePool(model, 3, 32)
    assert pool_capacity(pool) == 3
    pool.shrink(1)
    assert pool_capacity(pool) == 2
    assert pool_capacity(_Pool(12)) == 12
