"""Multi-tenant serving tests: registry/slack arithmetic, the greedy
allocator against hand-computed splits, the optimistic serve profiler's
knees, SLO-slack admission and preemption ordering, per-tenant stats (the
``unfinished`` accounting), and the tenant-isolation exactness invariant —
a mixed-tenant run is token-identical to the single-tenant reference on
both cache backends."""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.opt import greedy_allocate
from repro.models.api import build_model
from repro.serve import (SLOSlack, CachePool, ContinuousScheduler,
                         ServeEngine, ServeRequest, Tenant, TenantAllocation,
                         TenantAllocator, TenantRegistry, TenantShare,
                         plan_allocation, profiles_from_requests)
from repro.obs import RunObs
from repro.serve.tenant import calibrate, profile_class, serve_rate


def _model(arch="llama3.2-1b"):
    return build_model(get_config(arch, smoke=True))


def _requests(cfg, lengths, arrivals=None, max_new=5, seed=5, tenants=None):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0.0] * len(lengths)
    tenants = tenants or ["default"] * len(lengths)
    return [ServeRequest(rng.integers(1, cfg.vocab_size, size=s)
                         .astype(np.int32),
                         max_new_tokens=max_new, arrival_time=a, tenant=t)
            for s, a, t in zip(lengths, arrivals, tenants)]


def _registry():
    return TenantRegistry([Tenant("lat", weight=2.0, slo_steps=12.0),
                           Tenant("batch")])


# ---------------------------------------------------------------------------
# registry + slack
# ---------------------------------------------------------------------------
def test_registry_register_get_and_duplicate():
    reg = _registry()
    assert reg.get("lat").slo_steps == 12.0
    assert "batch" in reg and "nope" not in reg
    assert reg.ids == ["batch", "lat"]
    with pytest.raises(ValueError):
        reg.register(Tenant("lat"))
    with pytest.raises(ValueError):
        Tenant("bad", weight=0.0)


def test_slack_arithmetic():
    reg = TenantRegistry([Tenant("t", slo_steps=10.0)])
    r = ServeRequest(np.arange(1, 4, dtype=np.int32), max_new_tokens=5,
                     arrival_time=2.0, tenant="t")
    r.output = [7, 7]
    # deadline 2 + 10 = 12; projected finish 6 + (5 - 2) = 9
    assert reg.slack(r, now=6.0) == 3.0
    # no SLO / unknown tenant -> infinite slack (orders last, preempts first)
    r.tenant = "unknown"
    assert reg.slack(r, 6.0) == math.inf
    reg2 = TenantRegistry([Tenant("t")])
    r.tenant = "t"
    assert reg2.slack(r, 6.0) == math.inf


# ---------------------------------------------------------------------------
# greedy allocator (core/opt.py)
# ---------------------------------------------------------------------------
def test_greedy_allocate_hand_computed_knees():
    # curve A: slope 1 up to 4; curve B: slope 0.5 up to 10. Greedy hands
    # A its 4 units first (higher marginal), then B the remaining 6.
    a = lambda x: float(min(x, 4))
    b = lambda x: 0.5 * float(min(x, 10))
    assert greedy_allocate([a, b], 10.0) == [4.0, 6.0]


def test_greedy_allocate_floors_and_weighted_remainder():
    flat = lambda x: 0.0
    # every curve flat: the remainder spreads round-robin, heaviest first
    assert greedy_allocate([flat, flat], 5.0, weights=[2.0, 1.0]) == [3.0, 2.0]
    with pytest.raises(ValueError):
        greedy_allocate([flat], 2.0, floors=[3.0])
    got = greedy_allocate([flat, flat], 6.0, floors=[4.0, 1.0])
    assert got[0] >= 4.0 and got[1] >= 1.0 and sum(got) == 6.0


# ---------------------------------------------------------------------------
# optimistic serve profiler
# ---------------------------------------------------------------------------
def test_calibrate_roundtrips_the_rate_model():
    t_tok, t_fixed, n, kmax = 2e-3, 8e-3, 4, 8
    r1 = serve_rate(8, 1, units_per_req=2, concurrency=n, t_tok=t_tok,
                    t_fixed=t_fixed)
    rk = serve_rate(8, kmax, units_per_req=2, concurrency=n, t_tok=t_tok,
                    t_fixed=t_fixed)
    got_tok, got_fixed = calibrate(r1, rk, n, kmax)
    assert got_tok == pytest.approx(t_tok, rel=1e-6)
    assert got_fixed == pytest.approx(t_fixed, rel=1e-6)


def test_profile_class_knees():
    # 4 requests of 2 units each: the units axis saturates at 8 of the 16
    # pool units, the K axis amortizes t_fixed away.
    p = profile_class("t", units_per_req=2, concurrency=4, total_units=16,
                      max_k=8)
    m = p.matrix
    assert m.rate(8, 8) == m.rate(16, 8)            # flat past the knee
    assert m.rate(4, 8) < m.rate(8, 8)              # climbing before it
    assert m.rate(8, 1) < m.rate(8, 8)              # K amortization
    assert m.best_second_axis(8, knee=0.999) <= 8
    assert p.lane_curve()(2) == 2 and p.lane_curve()(9) == 4


def test_allocator_hand_computed_donation():
    """lat wants 2 units (2 x 1), batch wants 8 (4 x 2): on a 10-unit pool
    the greedy split lands exactly on the knees — the insensitive tenant
    cannot hoard units past where its curve flattens."""
    reg = _registry()
    profiles = {
        "lat": profile_class("lat", units_per_req=1, concurrency=2,
                             total_units=10, max_k=8),
        "batch": profile_class("batch", units_per_req=2, concurrency=4,
                               total_units=10, max_k=8),
    }
    alloc = TenantAllocator(reg, profiles).plan(10, total_lanes=4, max_k=8,
                                                watermark_units=2)
    lat, bat = alloc.share("lat"), alloc.share("batch")
    assert lat.units == 2 and bat.units == 8
    assert lat.units + bat.units == alloc.total_units
    assert 1 <= lat.k_cap <= 8 and 1 <= bat.k_cap <= 8
    assert lat.lanes >= 1 and bat.lanes >= 1
    assert lat.lanes + bat.lanes <= 4
    assert lat.headroom + bat.headroom == 2
    assert alloc.reserves() == {"lat": lat.headroom, "batch": bat.headroom}
    # horizon cap for a boundary: the LARGEST knee among the active tenants
    assert alloc.k_cap_for({"lat", "batch"}) == max(lat.k_cap, bat.k_cap)
    assert alloc.k_cap_for(set()) == 8


def test_allocator_missing_profile_raises():
    with pytest.raises(ValueError, match="no serve profile"):
        TenantAllocator(_registry(), {})


def test_admissible_budget_and_no_starvation():
    share = TenantShare("batch", units=1, k_cap=8, lanes=1, headroom=0)
    alloc = TenantAllocation(shares={"batch": share}, total_units=4, max_k=8)
    pool = object()                                  # slot pool: 1 unit/req
    r1 = ServeRequest(np.arange(1, 4, dtype=np.int32), tenant="batch")
    r2 = ServeRequest(np.arange(1, 4, dtype=np.int32), tenant="batch")
    free = ServeRequest(np.arange(1, 4, dtype=np.int32), tenant="lat")
    assert alloc.admissible(r1, {}, pool)            # first request: always
    r1.slot = 0
    assert not alloc.admissible(r2, {0: r1}, pool)   # over the 1-unit budget
    assert alloc.admissible(free, {0: r1}, pool)     # no share -> no budget


# ---------------------------------------------------------------------------
# SLO-slack ordering: admission + preemption
# ---------------------------------------------------------------------------
def test_slo_slack_admission_ordering():
    model = _model()
    cfg = get_config("llama3.2-1b", smoke=True)
    reg = _registry()

    def submit(policy):
        sched = ContinuousScheduler(CachePool(model, 1, 32), policy)
        reqs = _requests(cfg, [4, 4], tenants=["batch", "lat"])
        for i, r in enumerate(reqs):
            r.job_id = i
            sched.submit(r)
        return sched.admit()[0].tenant

    # FCFS tie-breaks on submission order -> the batch request wins the
    # single slot; slack ordering puts the SLO-carrying tenant first.
    assert submit("fcfs") == "batch"
    assert submit(SLOSlack(reg)) == "lat"


def test_preemption_victim_is_largest_slack():
    """Pool pressure with a tenant registry must land on the tenant that
    can absorb it (no SLO -> infinite slack) even when the SLO tenant was
    admitted LATER — the recency rule would pick the opposite victim —
    and outputs still match the static reference exactly."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    reg = TenantRegistry([Tenant("lat", slo_steps=40.0), Tenant("batch")])

    def reqs():
        return _requests(cfg, [8, 8], arrivals=[0.0, 2.0], max_new=8,
                         tenants=["batch", "lat"])

    static, _ = ServeEngine(cfg, params=params, max_len=32).run(
        _requests(cfg, [8, 8], max_new=8))
    # both requests grow to 16 tokens = 4 blocks; 6 blocks force preemption
    out, st = ServeEngine(cfg, params=params, max_len=32, n_slots=2,
                          cache="paged", block_size=4, n_blocks=6,
                          watermark=0.0, tenants=reg).run(reqs())
    assert st.preemptions >= 1
    by_tenant = {r.tenant: r for r in out}
    assert by_tenant["batch"].n_preempted >= 1
    assert by_tenant["lat"].n_preempted == 0
    for a, b in zip(static, out):
        assert a.output == b.output


# ---------------------------------------------------------------------------
# tenant-aware horizon choice
# ---------------------------------------------------------------------------
class _FakeSched:
    def __init__(self, active, waiting, step):
        self.active, self.waiting, self.step = active, waiting, step

    def next_arrival(self):
        return min((r.arrival_time for r in self.waiting), default=None)


def test_pick_h_allocation_k_cap_and_waiting_slack():
    cfg = get_config("llama3.2-1b", smoke=True)
    reg = _registry()
    shares = {"batch": TenantShare("batch", units=8, k_cap=2, lanes=1,
                                   headroom=0),
              "lat": TenantShare("lat", units=8, k_cap=8, lanes=1,
                                 headroom=0)}
    alloc = TenantAllocation(shares=shares, total_units=16, max_k=8)
    eng = ServeEngine(cfg, max_len=32, decode_horizon=8, tenants=reg,
                      allocation=alloc)
    running = ServeRequest(np.arange(1, 5, dtype=np.int32),
                           max_new_tokens=100, tenant="batch")
    running.slot = 0
    # active tenant's knee caps the horizon: k_cap=2 beats decode_horizon=8
    assert eng._pick_h(_FakeSched({0: running}, [], 0), [0]) == 2
    # a queued SLO request with slack 3 shrinks h toward the boundary
    running2 = ServeRequest(np.arange(1, 5, dtype=np.int32),
                            max_new_tokens=100, tenant="lat")
    running2.slot = 0
    urgent = ServeRequest(np.arange(1, 5, dtype=np.int32), max_new_tokens=3,
                          arrival_time=0.0, tenant="lat")  # slack = 12 - 3
    sched = _FakeSched({0: running2}, [urgent], 6)
    assert eng._pick_h(sched, [0]) == 2               # pow2_floor(12-3-6)=2


# ---------------------------------------------------------------------------
# per-tenant stats + the unfinished accounting
# ---------------------------------------------------------------------------
def _obs(steps):
    """A RunObs whose step clock reads ``steps`` (what run() hands _stats)."""
    c = RunObs()
    c.inc("steps", steps)
    return c


def _stamped(cfg, tenant, steps, wall, seed=0):
    r = ServeRequest(np.arange(1, 5, dtype=np.int32), max_new_tokens=2,
                     arrival_time=0.0, tenant=tenant)
    r.output = [1, 2]
    r.finished_at = float(steps)
    r.t_arrived, r.t_finished = 0.0, float(wall)
    return r


def test_stats_unfinished_cannot_inflate_attainment():
    """A dropped request (done but never wall-clock stamped) counts as
    ``unfinished`` and an SLO miss — attainment reflects ALL requests."""
    cfg = get_config("llama3.2-1b", smoke=True)
    reg = TenantRegistry([Tenant("lat", slo_steps=10.0)])
    eng = ServeEngine(cfg, max_len=32, tenants=reg)
    ok = _stamped(cfg, "lat", steps=5, wall=0.1)
    dropped = ServeRequest(np.arange(1, 5, dtype=np.int32), max_new_tokens=2,
                           tenant="lat")
    dropped.output = [1, 2]                  # done...
    dropped.finished_at = 5.0                # ...step clock stamped...
    assert dropped.latency_s is None         # ...but no wall stamps
    stats = eng._stats([ok, dropped], _obs(8), n_slots=2, wall=1.0)
    assert stats.unfinished == 1
    assert stats.slo_attainment == 0.5
    assert stats.tenants["lat"]["unfinished"] == 1
    assert stats.tenants["lat"]["slo_attainment"] == 0.5
    assert stats.tenants["lat"]["slo_steps"] == 10.0


def test_stats_slo_miss_on_each_clock():
    cfg = get_config("llama3.2-1b", smoke=True)
    reg = TenantRegistry([Tenant("t", slo_steps=10.0, slo_s=1.0)])
    eng = ServeEngine(cfg, max_len=32, tenants=reg)
    fast = _stamped(cfg, "t", steps=5, wall=0.1)
    slow_steps = _stamped(cfg, "t", steps=20, wall=0.1)
    slow_wall = _stamped(cfg, "t", steps=5, wall=5.0)
    stats = eng._stats([fast, slow_steps, slow_wall], _obs(20),
                       n_slots=2, wall=1.0)
    assert stats.slo_attainment == pytest.approx(1 / 3)
    assert stats.unfinished == 0


def test_tenant_stats_none_without_tags_or_registry():
    cfg = get_config("llama3.2-1b", smoke=True)
    eng = ServeEngine(cfg, max_len=32)
    reqs = [_stamped(cfg, "default", 3, 0.1)]
    assert eng._stats(reqs, _obs(4), n_slots=1, wall=1.0).tenants is None


def test_engine_validates_tenant_wiring():
    cfg = get_config("llama3.2-1b", smoke=True)
    with pytest.raises(ValueError, match="slo"):
        ServeEngine(cfg, max_len=32, policy="slo")
    alloc = TenantAllocation(shares={}, total_units=4, max_k=8)
    with pytest.raises(ValueError, match="TenantRegistry"):
        ServeEngine(cfg, max_len=32, allocation=alloc)


# ---------------------------------------------------------------------------
# tenant isolation: the exactness invariant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["llama3.2-1b", "olmoe-1b-7b"])
@pytest.mark.parametrize("cache", ["contiguous", "paged"])
def test_mixed_tenant_run_token_identical(arch, cache):
    """Every tenant mechanism reorders WHO runs WHEN — never what a request
    computes: a mixed-tenant SLO run with planned budgets must emit exactly
    the tokens of the untagged single-tenant static reference."""
    cfg = get_config(arch, smoke=True)
    params = build_model(cfg).init(jax.random.key(0))
    lengths, arrivals = [5, 3, 7, 4], [0.0, 1.0, 2.0, 3.0]
    tags = ["batch", "lat", "batch", "lat"]
    reg = _registry()

    static, _ = ServeEngine(cfg, params=params, max_len=32).run(
        _requests(cfg, lengths, max_new=5))

    reqs = _requests(cfg, lengths, arrivals=arrivals, max_new=5, tenants=tags)
    kw = dict(cache="paged", block_size=4, n_blocks=12,
              watermark=0.0) if cache == "paged" else {}
    total = 12 if cache == "paged" else 2
    units_for = ((lambda r: -(-(len(r.prompt) + r.max_new_tokens) // 4))
                 if cache == "paged" else None)
    profiles = profiles_from_requests(reg, reqs, total_units=total,
                                      units_for=units_for, max_k=4)
    alloc = plan_allocation(reg, profiles, total, total_lanes=2, max_k=4,
                            watermark_units=1 if cache == "paged" else 0)
    out, st = ServeEngine(cfg, params=params, max_len=32, n_slots=2,
                          policy="slo", decode_horizon=4, tenants=reg,
                          allocation=alloc, **kw).run(reqs)
    assert st.tenants is not None and set(st.tenants) == {"batch", "lat"}
    for a, b in zip(static, out):
        assert a.output == b.output
