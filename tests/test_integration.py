"""Integration tests: pipeline -> trainer -> checkpoint -> serve, the Synergy
iterator lease path, and the live runtime end-to-end (scaled down)."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.iterator import ControlChannel, SynergyIterator
from repro.data.minio import MinIOCache
from repro.data.pipeline import DataConfig, DataPipeline
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def test_train_loss_decreases_and_ckpt_resumes(tmp_path):
    cfg = get_config("llama3.2-1b", smoke=True)
    dc = DataConfig(n_samples=256, seq_len=32, vocab_size=cfg.vocab_size)
    pipe = DataPipeline(dc, batch_size=8)
    ck = str(tmp_path / "t.ckpt")
    tr = Trainer(cfg, TrainerConfig(total_steps=20, peak_lr=1e-3,
                                    ckpt_path=ck, ckpt_every=10))
    hist = tr.fit(pipe.batches(20))
    assert hist[-1]["loss"] < hist[0]["loss"]

    tr2 = Trainer(cfg, TrainerConfig(total_steps=20, ckpt_path=ck))
    assert tr2.maybe_restore()
    assert tr2.step == 20
    # resumed params identical
    l1 = jax.tree_util.tree_leaves(tr.state["params"])
    l2 = jax.tree_util.tree_leaves(tr2.state["params"])
    assert all(jnp.allclose(a, b) for a, b in zip(l1, l2))


def test_serve_engine_prefill_consistency():
    """Engine decode continues exactly where teacher forcing leaves off."""
    cfg = get_config("llama3.2-1b", smoke=True)
    eng = ServeEngine(cfg, max_len=32)
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    out = eng.generate([Request(prompt, max_new_tokens=4)])[0].output
    # manual greedy decode via forward
    toks = list(prompt)
    for _ in range(4):
        logits = eng.model.forward(eng.params,
                                   {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert out == toks[len(prompt):]


def test_synergy_iterator_lease_updates_apply():
    dc = DataConfig(n_samples=64, seq_len=16, vocab_size=128)
    pipe = DataPipeline(dc, batch_size=4, n_workers=1)
    ch = ControlChannel(0)
    it = SynergyIterator(0, pipe, ch)
    gen = iter(it)
    next(gen)
    ch.send_lease(cpus=3, mem_gb=0.25)
    next(gen)
    assert pipe.n_workers == 3
    assert pipe.cache.capacity_bytes == int(0.25 * (1 << 30))
    # progress reports flowed
    assert ch.drain_progress()
    # terminate -> checkpoint callback + stop
    called = []
    it.on_terminate = lambda: called.append(1)
    ch.terminate()
    remaining = list(gen)
    assert called and it.terminated
    assert len(remaining) == 0 or remaining is not None


def test_minio_hit_rate_scales_throughput():
    """Bigger cache -> fewer (virtual) fetch seconds for one epoch."""
    results = {}
    for gb in (0.0, 0.03, 0.06):
        dc = DataConfig(n_samples=64, seq_len=16, vocab_size=128,
                        sample_bytes=1 << 20, simulate_io=True)
        pipe = DataPipeline(dc, batch_size=8)
        pipe.set_cache_gb(gb)
        for _ in pipe.batches(8):
            pass
        results[gb] = pipe.virtual_fetch_seconds
    assert results[0.0] > results[0.03] > results[0.06]


@pytest.mark.slow
def test_live_runtime_end_to_end():
    from repro.core.runtime import LiveJobSpec, LiveRuntime
    rt = LiveRuntime(n_servers=1, policy="srtf", allocator="tune",
                     round_seconds=1.0, probe_iters=1)
    rt.submit(LiveJobSpec(0, "qwen2-0.5b", total_iters=6, batch_size=2,
                          preprocess_cost_s=0.001, dataset_gb=0.05,
                          seq_len=16))
    rt.submit(LiveJobSpec(1, "llama3.2-1b", total_iters=6, batch_size=2,
                          preprocess_cost_s=0.004, dataset_gb=0.05,
                          seq_len=16))
    m = rt.run(max_rounds=40)
    assert m["finished"] == 2, m
    assert m["avg_jct"] > 0


def test_dryrun_single_combo_smoke():
    """Lower+compile one combo in-process on the 512-device mesh (only when
    the device-count flag is already set — runs under the sweep env)."""
    if jax.device_count() < 512:
        pytest.skip("requires --xla_force_host_platform_device_count=512")
    from repro.launch.dryrun import lower_combo
    rec, _ = lower_combo("llama3.2-1b", "decode_32k", False, probe=False)
    assert rec["n_chips"] == 256
    assert rec["bottleneck"] in ("compute", "memory", "collective")
