"""Observability tests: tracer ring semantics, metrics math, event-schema
stability (golden trace), tracing-on token identity, and the offline
trace_report analyzer."""
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.trace_report import build_report
from repro.obs import (EVENT_SCHEMA, NULL_TRACER, SPAN_EVENTS, Histogram,
                       MetricsRegistry, NullTracer, Tracer, load_trace,
                       to_chrome_trace, validate_events)
from repro.serve import (ServeEngine, ServeRequest, Tenant, TenantRegistry)


def _requests(cfg, lengths, max_new=4, arrivals=None, tenants=None, seed=11):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0.0] * len(lengths)
    tenants = tenants or ["default"] * len(lengths)
    return [ServeRequest(rng.integers(1, cfg.vocab_size, size=s)
                         .astype(np.int32), max_new_tokens=max_new,
                         arrival_time=a, tenant=t)
            for s, a, t in zip(lengths, arrivals, tenants)]


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------
def test_tracer_ring_overflow_drops_oldest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.emit("defer", req=i, tenant="t", cause="test")
    assert len(tr) == 4
    assert tr.dropped == 6
    # the ring keeps the TAIL of the stream (newest events)
    assert [e["req"] for e in tr.events] == [6, 7, 8, 9]


def test_tracer_step_clock_and_wall_time():
    tr = Tracer()
    tr.step = 7.0
    tr.emit("prefix_evict", blocks=1)
    tr.emit("prefix_evict", step=3.0, blocks=2)   # explicit step override
    a, b = tr.events
    assert a["step"] == 7.0 and b["step"] == 3.0
    assert 0.0 <= a["t"] <= b["t"]


def test_tracer_capacity_validated():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_null_tracer_is_falsy_noop():
    assert not NullTracer()
    assert not NULL_TRACER
    NULL_TRACER.emit("admit", req=1)       # no-op, no error
    NULL_TRACER.step = 5.0                 # engine advances it freely
    assert NULL_TRACER.events == []


def test_dump_and_load_roundtrip(tmp_path):
    tr = Tracer(capacity=8)
    tr.emit("prefix_evict", blocks=1)
    tr.emit("defer", req=0, tenant="t0", cause="prefix_unready")
    path = str(tmp_path / "t.jsonl")
    tr.dump_jsonl(path)
    events = load_trace(path)
    assert events[0]["ev"] == "trace_meta"
    assert events[0]["events"] == 2 and events[0]["capacity"] == 8
    assert [e["ev"] for e in events[1:]] == ["prefix_evict", "defer"]
    assert validate_events(events) == []


def test_validate_events_catches_drift():
    ok = {"ev": "defer", "step": 0.0, "t": 0.0,
          "req": 1, "tenant": "t", "cause": "x"}
    assert validate_events([ok]) == []
    bad = [
        {"ev": "not_a_type", "step": 0.0, "t": 0.0},
        {"ev": "defer", "step": 0.0, "t": 0.0, "req": 1},       # missing
        {**ok, "extra_field": 1},                               # extra
    ]
    problems = validate_events(bad)
    assert len(problems) == 3
    assert "unknown type" in problems[0]
    assert "missing=['cause', 'tenant']" in problems[1]
    assert "extra=['extra_field']" in problems[2]


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    xs = rng.normal(size=501)
    h = Histogram("x")
    for v in xs:
        h.record(v)
    for q in (0, 10, 50, 95, 99, 100):
        assert h.percentile(q) == pytest.approx(np.percentile(xs, q))
    assert h.mean == pytest.approx(xs.mean())
    s = h.summary()
    assert s["count"] == 501
    assert s["min"] == pytest.approx(xs.min())
    assert s["max"] == pytest.approx(xs.max())


def test_histogram_overflow_decimates_but_keeps_exact_extremes():
    h = Histogram("x", max_samples=64)
    for v in range(1000):
        h.record(float(v))
    assert h.count == 1000
    assert h.vmin == 0.0 and h.vmax == 999.0
    assert len(h.values) <= 64
    # decimated percentiles stay close to the true distribution
    assert h.percentile(50) == pytest.approx(499.5, abs=40)
    with pytest.raises(ValueError):
        h.percentile(101)


def test_registry_counters_gauges_series():
    m = MetricsRegistry()
    m.inc("steps", 4)
    m.inc("steps")
    m.set("queue_depth", 3)
    m.hi("max_active", 2)
    m.hi("max_active", 1)                  # high watermark keeps the max
    assert m.value("steps") == 5.0
    assert m.value("max_active") == 2.0
    assert m.value("missing", -1.0) == -1.0
    m.sample(step=8)
    m.set("queue_depth", 1)
    m.sample(step=16)
    mean, peak = m.series_stats("queue_depth")
    assert (mean, peak) == (2.0, 3.0)
    # fallback: an unsampled name reports its live value as a flat series
    m.set("fresh", 7.0)
    assert m.series_stats("fresh") == (7.0, 7.0)
    summ = m.summary()
    assert summ["counters"]["steps"] == 5.0
    assert summ["series"]["queue_depth"] == 2


# ---------------------------------------------------------------------------
# golden trace: event-schema stability on a small deterministic run
# ---------------------------------------------------------------------------
def test_golden_trace_contiguous():
    cfg = get_config("llama3.2-1b", smoke=True)
    tr = Tracer()
    ServeEngine(cfg, max_len=16, n_slots=2, tracer=tr).run(
        _requests(cfg, [5, 7]))
    assert [e["ev"] for e in tr.events] == [
        "run_start", "admit", "admit", "prefill", "prefill",
        "decode_horizon", "decode_horizon", "evict", "evict", "run_end"]
    assert validate_events(tr.events) == []
    start = tr.events[0]
    assert start["backend"] == "contiguous" and start["n_requests"] == 2


def test_golden_trace_paged():
    cfg = get_config("llama3.2-1b", smoke=True)
    tr = Tracer()
    ServeEngine(cfg, max_len=16, n_slots=2, cache="paged", block_size=4,
                tracer=tr).run(_requests(cfg, [5, 7]))
    assert [e["ev"] for e in tr.events] == [
        "run_start", "block_alloc", "admit", "block_alloc", "admit",
        "prefill_round", "prefill_round", "block_grow",
        "decode_horizon", "decode_horizon",
        "block_free", "block_free", "evict", "evict", "run_end"]
    assert validate_events(tr.events) == []


def test_tracing_on_token_identity_paged_churn():
    """Tracing must observe, never perturb: a churny paged config (tiny
    pool, staggered arrivals, prefix cache) produces token-identical
    outputs with and without a tracer attached."""
    cfg = get_config("llama3.2-1b", smoke=True)
    kw = dict(max_len=32, n_slots=3, cache="paged", block_size=4,
              n_blocks=14, prefix_cache=True)
    mk = lambda: _requests(cfg, [7, 12, 5, 9], max_new=6,  # noqa: E731
                           arrivals=[0.0, 0.0, 2.0, 4.0])
    off, s_off = ServeEngine(cfg, **kw).run(mk())
    tr = Tracer()
    on, s_on = ServeEngine(cfg, tracer=tr, **kw).run(mk())
    assert [r.output for r in on] == [r.output for r in off]
    assert s_on.steps == s_off.steps
    assert s_on.decode_dispatches == s_off.decode_dispatches
    assert validate_events(tr.events) == []
    kinds = {e["ev"] for e in tr.events}
    assert {"block_alloc", "block_free", "decode_horizon",
            "prefill_round"} <= kinds


def test_stats_queue_and_occupancy_summaries_without_tracing():
    """The metrics half is always on: queue-depth / occupancy summaries
    exist on a plain untraced run."""
    cfg = get_config("llama3.2-1b", smoke=True)
    _, st = ServeEngine(cfg, max_len=32, n_slots=2).run(
        _requests(cfg, [7, 12, 5, 9], max_new=6,
                  arrivals=[0.0, 0.0, 2.0, 4.0]))
    assert st.max_queue_depth >= 1            # 4 requests over 2 slots queue
    assert st.mean_queue_depth > 0.0
    assert 0.0 < st.mean_occupancy <= 1.0
    assert st.max_occupancy == 1.0


# ---------------------------------------------------------------------------
# chrome export
# ---------------------------------------------------------------------------
def test_chrome_export_structure():
    tr = Tracer()
    tr.emit("admit", req=0, tenant="t0", slot=1, prompt_len=5, max_new=4,
            wait_steps=0.0, units=2)
    tr.emit("decode_horizon", k=8, width=4, active=3, full=False,
            dur_s=0.25)
    doc = to_chrome_trace(tr.events)
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in meta)
    tracks = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert {"scheduler", "prefill", "decode", "pool"} <= tracks
    admit = next(e for e in evs if e.get("name") == "admit")
    assert admit["ph"] == "i" and admit["args"]["tenant"] == "t0"
    span = next(e for e in evs if e["ph"] == "X")
    assert span["name"] == "decode[K=8,W=4]"
    assert span["dur"] == pytest.approx(0.25 * 1e6)
    assert span["ts"] >= 0.0
    json.dumps(doc)                       # serializable as written


def test_chrome_tracks_cover_schema():
    """Every span type renders as a duration; every schema type that the
    engine emits maps onto a track."""
    from repro.obs.chrome import _TRACKS
    assert SPAN_EVENTS <= set(_TRACKS)
    assert set(EVENT_SCHEMA) - {"trace_meta"} == set(_TRACKS)


# ---------------------------------------------------------------------------
# trace_report analyzer
# ---------------------------------------------------------------------------
def test_trace_report_two_tenant_run(tmp_path):
    """End-to-end: a two-tenant paged run under pool pressure -> JSONL ->
    analyzer. The report must reconstruct non-empty SLO timelines, both
    tenants' occupancy shares, and the preemption-cause table."""
    cfg = get_config("llama3.2-1b", smoke=True)
    registry = TenantRegistry([Tenant("lat", slo_steps=16.0),
                               Tenant("batch")])
    tr = Tracer()
    eng = ServeEngine(cfg, max_len=32, n_slots=2, cache="paged",
                      block_size=4, n_blocks=7, watermark=0.0,
                      tenants=registry, policy="slo", tracer=tr)
    out, st = eng.run(_requests(
        cfg, [6, 6, 4, 4], max_new=8, arrivals=[0.0, 0.0, 1.0, 3.0],
        tenants=["batch", "batch", "lat", "lat"]))
    assert all(r.done for r in out)
    assert st.preemptions > 0             # the pool is sized to churn
    assert validate_events(tr.events) == []

    path = str(tmp_path / "trace.jsonl")
    tr.dump_jsonl(path)
    report = build_report(load_trace(path), n_buckets=4)
    assert report["meta"]["dropped"] == 0
    assert report["run"]["backend"] == "paged"
    assert set(report["slo_timeline"]) == {"lat", "batch"}
    for buckets in report["slo_timeline"].values():
        assert sum(b["n"] for b in buckets) > 0
    shares = report["occupancy_shares"]
    assert set(shares) == {"lat", "batch"}
    assert sum(s["share"] for s in shares.values()) == pytest.approx(1.0)
    assert report["preemptions"]
    assert all(row["cause"] == "pool_pressure"
               for row in report["preemptions"])
    assert report["dispatches"]["decode"]["dispatches"] >= 1
    assert report["queue"]["lat"]["admitted"] == 2


def test_trace_report_empty_timeline_flag(tmp_path):
    """--require-slo-timeline is the CI assertion: a trace with no evict
    events exits nonzero."""
    from repro.launch.trace_report import main
    tr = Tracer()
    tr.emit("run_start", backend="paged", n_slots=2, horizon=8,
            n_requests=0)
    path = str(tmp_path / "empty.jsonl")
    tr.dump_jsonl(path)
    assert main([path, "--require-slo-timeline"]) == 1
    assert main([path]) == 0
