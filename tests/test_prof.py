"""Dispatch-profiler tests: NULL contract, compile attribution, roofline
terms and gauges, profiling-on token identity, ProfileStore persistence +
rate fits, the measured-calibrate path in serve/tenant.py, and the
downstream renderers (trace_report phase costs, Chrome counter track,
roofline table's None-safe formatting)."""
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.roofline import fmt_row
from repro.launch.trace_report import build_report, phase_costs
from repro.obs import (NULL_PROFILER, DispatchProfiler, NullDispatchProfiler,
                       ProfileStore, RunObs, Tracer, to_chrome_trace,
                       validate_events)
from repro.serve import ServeEngine, ServeRequest
from repro.serve.tenant import profile_class


def _requests(cfg, lengths, max_new=4, arrivals=None, tenants=None, seed=11):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0.0] * len(lengths)
    tenants = tenants or ["default"] * len(lengths)
    return [ServeRequest(rng.integers(1, cfg.vocab_size, size=s)
                         .astype(np.int32), max_new_tokens=max_new,
                         arrival_time=a, tenant=t)
            for s, a, t in zip(lengths, arrivals, tenants)]


# ---------------------------------------------------------------------------
# NULL contract
# ---------------------------------------------------------------------------
def test_null_profiler_is_falsy_noop():
    assert not NullDispatchProfiler()
    assert not NULL_PROFILER
    NULL_PROFILER.record("decode", 0.1, width=4, k=8)      # no-op, no error
    assert NULL_PROFILER.summary() == {}
    assert NULL_PROFILER.records == [] and NULL_PROFILER.tenant_s == {}


def test_engine_defaults_to_null_profiler():
    cfg = get_config("llama3.2-1b", smoke=True)
    eng = ServeEngine(cfg, max_len=16, n_slots=2)
    assert eng.profiler is NULL_PROFILER
    assert not eng.profiler


# ---------------------------------------------------------------------------
# compile-vs-execute attribution + roofline terms
# ---------------------------------------------------------------------------
def test_compile_attribution_per_signature():
    prof = DispatchProfiler()                  # shape-free: pure attribution
    a = prof.record("decode", 0.5, width=4, k=8, full=False)
    b = prof.record("decode", 0.01, width=4, k=8, full=False)
    c = prof.record("decode", 0.4, width=4, k=8, full=True)   # new signature
    d = prof.record("decode", 0.3, width=2, k=8, full=False)  # new signature
    assert [r["compile"] for r in (a, b, c, d)] == [True, False, True, True]
    assert a["sig"] == "decode/W4/K8/gather" and c["sig"] == "decode/W4/K8/full"
    agg = prof.by_signature()["decode/W4/K8/gather"]
    assert agg["n"] == 2 and agg["compiles"] == 1
    assert agg["compile_s"] == pytest.approx(0.5)
    assert agg["mean_execute_s"] == pytest.approx(0.01)


def test_roofline_terms_nonzero_and_util_gauge():
    cfg = get_config("qwen2-0.5b", smoke=True)
    prof = DispatchProfiler(cfg)
    flops, hbm = prof.roofline_terms("decode", tokens=32, k=8, kv_pos_sum=100)
    assert flops > 0 and hbm > 0
    # decode re-reads the weights every scan step: k scales the byte term
    _, hbm1 = prof.roofline_terms("decode", tokens=32, k=1, kv_pos_sum=100)
    assert hbm > hbm1
    obs = RunObs()
    prof.record("decode", 0.5, width=4, k=8, obs=obs)          # compile
    rec = prof.record("decode", 0.02, width=4, k=8, obs=obs)   # execute
    assert rec["util"] is not None and rec["util"] > 0
    assert obs.metrics.gauge("util[decode]").value == pytest.approx(rec["util"])
    assert obs.value("compile_s[decode]") == pytest.approx(0.5)
    assert obs.value("execute_s[decode]") == pytest.approx(0.02)


def test_tenant_cost_shares_split_by_rows():
    prof = DispatchProfiler()
    prof.record("decode", 0.4, width=4, k=2, tenants={"a": 3, "b": 1})
    prof.record("decode", 0.2, width=2, k=2, tenants={"b": 2})
    s = prof.summary()
    assert s["tenant_seconds"]["a"] == pytest.approx(0.3)
    assert s["tenant_seconds"]["b"] == pytest.approx(0.3)
    assert s["tenant_shares"]["a"] == pytest.approx(0.5)
    assert s["dispatches"] == 2 and s["signatures"] == 2


# ---------------------------------------------------------------------------
# profiling must observe, never perturb
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["llama3.2-1b", "olmoe-1b-7b"])
@pytest.mark.parametrize("cache", ["contiguous", "paged"])
def test_profiled_run_token_identity(arch, cache):
    """Traced + profiled run is token-identical to the bare run, on a dense
    and a moe arch, on both cache backends."""
    cfg = get_config(arch, smoke=True)
    kw = dict(max_len=24, n_slots=2, cache=cache)
    if cache == "paged":
        kw["block_size"] = 4
    mk = lambda: _requests(cfg, [5, 7, 4], max_new=4,  # noqa: E731
                           arrivals=[0.0, 0.0, 2.0])
    bare, s_bare = ServeEngine(cfg, **kw).run(mk())
    prof = DispatchProfiler(cfg)
    tr = Tracer()
    on, s_on = ServeEngine(cfg, tracer=tr, profiler=prof, **kw).run(mk())
    assert [r.output for r in on] == [r.output for r in bare]
    assert s_on.steps == s_bare.steps
    assert s_on.decode_dispatches == s_bare.decode_dispatches
    assert len(prof.records) > 0
    assert validate_events(tr.events) == []
    assert any(e["ev"] == "dispatch_profile" for e in tr.events)


def test_profiled_run_emits_compile_split_and_util():
    """A warm second run on the same engine yields execute records with
    nonzero utilization, surfaced as the decode_util stat."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    prof = DispatchProfiler(cfg)
    eng = ServeEngine(cfg, max_len=24, n_slots=2, cache="paged",
                      block_size=4, profiler=prof)
    eng.run(_requests(cfg, [5, 7]))
    _, st = eng.run(_requests(cfg, [5, 7]))
    assert any(r["compile"] for r in prof.records)
    assert any(not r["compile"] for r in prof.records)
    utils = [r["util"] for r in prof.records if r["util"] is not None]
    assert utils and all(u > 0 for u in utils)
    assert st.decode_util > 0
    s = prof.summary()
    assert s["phases"]["decode"]["compiles"] >= 1
    assert s["phases"]["decode"]["execute_s"] > 0


# ---------------------------------------------------------------------------
# ProfileStore
# ---------------------------------------------------------------------------
def _synthetic_decode(width, k, mean_s, n=4, arch="a1", backend="paged"):
    return {"source": "serve", "arch": arch, "backend": backend,
            "mesh": None, "phase": "decode", "sig": f"decode/W{width}/K{k}",
            "width": width, "k": k, "tokens": width * k, "n": n,
            "compiles": 1, "compile_s": 0.5, "mean_s": mean_s,
            "flops": 1e9, "hbm_bytes": 1e8, "util": 0.1}


def test_store_roundtrip_and_keyed_merge(tmp_path):
    path = str(tmp_path / "p.jsonl")
    store = ProfileStore()
    store.add(_synthetic_decode(4, 8, 0.020))
    store.add(_synthetic_decode(2, 8, 0.012))
    store.add(_synthetic_decode(4, 8, 0.021))      # same key: supersedes
    assert len(store) == 2
    store.save(path)
    back = ProfileStore.load(path)
    assert len(back) == 2
    rec = {r["sig"]: r for r in back.records}["decode/W4/K8"]
    assert rec["mean_s"] == pytest.approx(0.021)
    # missing file is an empty store, not an error
    assert len(ProfileStore.load(str(tmp_path / "nope.jsonl"))) == 0


def test_rate_fit_recovers_synthetic_constants():
    t_tok, t_fixed = 2.5e-4, 8e-3
    store = ProfileStore()
    for w, k in [(1, 8), (2, 8), (4, 8), (4, 4)]:
        store.add(_synthetic_decode(w, k, t_fixed + w * k * t_tok))
    fit = store.rate_fit("a1", "paged")
    assert fit is not None
    assert fit[0] == pytest.approx(t_tok, rel=1e-6)
    assert fit[1] == pytest.approx(t_fixed, rel=1e-6)
    # single dispatch size: underdetermined -> None
    one = ProfileStore([_synthetic_decode(4, 8, 0.02)])
    assert one.rate_fit("a1", "paged") is None
    # wrong arch / backend filters
    assert store.rate_fit("other") is None
    assert store.rate_fit("a1", "contiguous") is None


def test_add_dryrun_record_conversion():
    store = ProfileStore()
    store.add_dryrun_record({
        "arch": "qwen2-0.5b", "shape": "decode_32k", "mesh": "host",
        "mode": "decode_step", "compute_s": 0.001, "memory_s": 0.004,
        "collective_s": 0.0, "bottleneck": "memory",
        "flops_per_chip": 1.2e12, "bytes_per_chip": 3.4e9,
        "useful_flop_ratio": 0.41})
    (r,) = store.records
    assert r["source"] == "dryrun" and r["phase"] == "decode_step"
    assert r["sig"] == "decode_step/decode_32k"
    assert r["mean_s"] == pytest.approx(0.004)       # max of the bound times
    assert r["bottleneck"] == "memory"
    # dryrun records never satisfy the serve-side rate fit
    assert store.rate_fit("qwen2-0.5b") is None


# ---------------------------------------------------------------------------
# measured-calibrate in serve/tenant.py
# ---------------------------------------------------------------------------
def test_profile_class_measured_source_from_store():
    t_tok, t_fixed = 3e-4, 5e-3
    store = ProfileStore()
    for w, k in [(1, 8), (2, 8), (4, 8)]:
        store.add(_synthetic_decode(w, k, t_fixed + w * k * t_tok))
    p = profile_class("t", units_per_req=2, concurrency=4, total_units=8,
                      store=store, arch="a1", backend="paged")
    assert p.source == "measured"
    assert p.t_tok == pytest.approx(t_tok, rel=1e-6)
    assert p.t_fixed == pytest.approx(t_fixed, rel=1e-6)


def test_profile_class_falls_back_to_analytic():
    # no store
    p = profile_class("t", units_per_req=2, concurrency=4, total_units=8)
    assert p.source == "analytic"
    # store without a usable fit (one dispatch size)
    store = ProfileStore([_synthetic_decode(4, 8, 0.02)])
    q = profile_class("t", units_per_req=2, concurrency=4, total_units=8,
                      store=store, arch="a1", backend="paged")
    assert q.source == "analytic"
    assert q.t_tok == p.t_tok and q.t_fixed == p.t_fixed


def test_probe_wins_over_store():
    store = ProfileStore()
    for w, k in [(1, 8), (4, 8)]:
        store.add(_synthetic_decode(w, k, 5e-3 + w * k * 3e-4))
    p = profile_class("t", units_per_req=2, concurrency=4, total_units=8,
                      probe=lambda k: 100.0 * k / (1 + 0.1 * k),
                      store=store, arch="a1", backend="paged")
    assert p.source == "probed"


# ---------------------------------------------------------------------------
# renderers
# ---------------------------------------------------------------------------
def test_fmt_row_handles_missing_probe_fields():
    """Regression: multipod/host records carry useful_flop_ratio=None and
    no flops_per_chip — fmt_row must render an em dash, not crash."""
    row = fmt_row({"arch": "a", "shape": "s", "mesh": "host",
                   "compute_s": 0.001, "memory_s": 0.002,
                   "collective_s": 0.0, "bottleneck": "memory",
                   "useful_flop_ratio": None, "flops_per_chip": None,
                   "memory_stats": None})
    assert "—" in row and "None" not in row


def test_chrome_renders_dispatch_profile_counters_and_instants():
    tr = Tracer()
    tr.emit("dispatch_profile", phase="decode", sig="decode/W4/K8/gather",
            dur_s=0.5, compile=True, tokens=32, flops=1e9, hbm_bytes=1e8,
            util=None)
    tr.emit("dispatch_profile", phase="decode", sig="decode/W4/K8/gather",
            dur_s=0.02, compile=False, tokens=32, flops=1e9, hbm_bytes=1e8,
            util=0.25)
    assert validate_events(tr.events) == []
    doc = to_chrome_trace(tr.events)
    evs = doc["traceEvents"]
    inst = next(e for e in evs if e["ph"] == "i" and "compile[" in e["name"])
    assert inst["name"] == "compile[decode/W4/K8/gather]"
    ctr = next(e for e in evs if e["ph"] == "C")
    assert ctr["name"] == "util[decode]"
    assert ctr["args"]["util"] == pytest.approx(0.25)
    tracks = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "profile" in tracks
    json.dumps(doc)


def test_trace_report_phase_costs(tmp_path):
    cfg = get_config("qwen2-0.5b", smoke=True)
    prof = DispatchProfiler(cfg)
    tr = Tracer()
    ServeEngine(cfg, max_len=24, n_slots=2, cache="paged", block_size=4,
                tracer=tr, profiler=prof).run(_requests(cfg, [5, 7]))
    path = str(tmp_path / "t.jsonl")
    tr.dump_jsonl(path)
    with open(path) as f:
        events = [json.loads(ln) for ln in f]
    rep = build_report(events[1:])
    rows = {r["phase"]: r for r in rep["phase_costs"]}
    assert "decode" in rows and "prefill_round" in rows
    assert rows["decode"]["count"] >= 1
    assert rows["decode"]["total_ms"] > 0
    assert rows["decode"]["compiles"] >= 1


def test_phase_costs_without_profiling():
    """A trace recorded without a profiler still yields the span-derived
    columns; util stays None."""
    events = [{"ev": "decode_horizon", "step": 0.0, "t": 0.0, "k": 4,
               "width": 2, "active": 2, "full": False, "dur_s": 0.01}]
    (row,) = phase_costs(events)
    assert row["phase"] == "decode" and row["count"] == 1
    assert row["util"] is None and row["compiles"] == 0
