"""The §Perf optimization knobs must be EXACT function-preserving rewrites:
banded local attention, no-repeat GQA, per-group Q-head padding, and the MoE
gather dispatch all produce the same outputs as the baseline paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model, make_batch


def _fwd_pair(cfg_base, cfg_opt, seq=64, seed=1):
    batch = make_batch(cfg_base, 2, seq, jax.random.key(seed))
    m0 = build_model(cfg_base)
    params = m0.init(jax.random.key(0))
    l0 = m0.forward(params, batch)
    l1 = build_model(cfg_opt).forward(params, batch)
    return np.asarray(l0), np.asarray(l1)


def test_banded_local_attention_exact():
    cfg = get_config("gemma3-27b", smoke=True).replace(
        n_layers=4, sliding_window=16, global_every=2, vocab_size=512)
    l0, l1 = _fwd_pair(cfg, cfg.replace(local_banded=True))
    np.testing.assert_allclose(l0, l1, atol=2e-3, rtol=2e-3)


def test_banded_requires_divisible_seq_falls_back():
    cfg = get_config("gemma3-27b", smoke=True).replace(
        n_layers=2, sliding_window=24, global_every=2, vocab_size=512,
        local_banded=True)
    # seq 64 % 24 != 0 -> must silently use the scanned path, not crash
    batch = make_batch(cfg, 1, 64, jax.random.key(0))
    m = build_model(cfg)
    logits = m.forward(m.init(jax.random.key(0)), batch)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_gqa_no_repeat_exact():
    cfg = get_config("llama3.2-1b", smoke=True)
    l0, l1 = _fwd_pair(cfg, cfg.replace(gqa_no_repeat=True), seq=32)
    np.testing.assert_allclose(l0, l1, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("pad", [6, 8])
def test_pad_q_heads_exact(pad):
    cfg = get_config("qwen2-0.5b", smoke=True)        # 4 heads, kv=2
    batch = make_batch(cfg, 2, 32, jax.random.key(3))
    m0 = build_model(cfg)
    l0 = m0.forward(m0.init(jax.random.key(0)), batch)
    m1 = build_model(cfg.replace(pad_q_heads=pad))
    l1 = m1.forward(m1.init(jax.random.key(0)), batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               atol=2e-3, rtol=2e-3)


def test_moe_gather_dispatch_exact():
    cfg = get_config("olmoe-1b-7b", smoke=True)
    l0, l1 = _fwd_pair(cfg, cfg.replace(moe_gather_dispatch=True))
    np.testing.assert_allclose(l0, l1, atol=2e-3, rtol=2e-3)


def test_moe_gather_dispatch_grads_match():
    cfg = get_config("olmoe-1b-7b", smoke=True)
    batch = make_batch(cfg, 2, 32, jax.random.key(2))
    m0 = build_model(cfg)
    params = m0.init(jax.random.key(0))
    g0 = jax.grad(m0.loss)(params, batch)
    g1 = jax.grad(build_model(cfg.replace(moe_gather_dispatch=True)).loss)(
        params, batch)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)
